from repro.kernels.ops import coded_matvec, lt_encode, ssd_forward  # noqa: F401
