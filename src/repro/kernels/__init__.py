from repro.kernels.ops import (  # noqa: F401
    coded_matvec,
    coded_matvec_decode,
    lt_encode,
    ssd_forward,
)
