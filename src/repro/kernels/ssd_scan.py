"""Pallas TPU kernels for the Mamba-2 SSD intra-chunk compute.

The SSD algorithm splits the sequence into chunks of Q; per chunk the work
is matmul-shaped (the whole point of state-space *duality*) and MXU-
friendly — these two kernels own it, while the O(S/Q) inter-chunk state
recurrence stays a jnp ``lax.scan`` (sequential, tiny, not kernel-worthy):

  kernel 1 (``ssd_chunk``):  per (group, chunk) grid cell
      L   = exp(segsum(dA))             [Q, Q]  fp32 in VMEM
      y   = (C Bᵀ ∘ L) · X              [Q, P]
      S_c = Xᵀ · (decay ∘ B)            [P, N]  chunk state contribution
  kernel 2 (``ssd_combine``): y += exp(cumsum dA) ∘ (C · S_inᵀ)

VMEM at Q=256, N=128, P=64 (fp32): L + CBᵀ 2x256 KB, X 64 KB, B/C 2x128 KB
≈ 0.85 MB per cell — comfortable; Q is the tuning knob (see §Perf).
Grid is (B·H, nc); head-expansion of grouped B/C happens in the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_pallas", "ssd_combine_pallas"]


def _chunk_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, st_ref, dec_ref, cum_ref):
    q = x_ref.shape[1]
    da = da_ref[0].astype(jnp.float32)                    # [Q]
    cum = jnp.cumsum(da)                                  # [Q]
    diff = cum[:, None] - cum[None, :]                    # [Q, Q]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    ell = jnp.where(mask, jnp.exp(diff), 0.0)
    c = c_ref[0].astype(jnp.float32)                      # [Q, N]
    b = b_ref[0].astype(jnp.float32)
    x = x_ref[0].astype(jnp.float32)                      # [Q, P]
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # [Q, Q]
    y_ref[0] = jnp.dot(cb * ell, x, preferred_element_type=jnp.float32)
    decay_states = jnp.exp(cum[-1] - cum)                 # [Q]
    st_ref[0] = jnp.dot(
        x.T, b * decay_states[:, None], preferred_element_type=jnp.float32
    )                                                     # [P, N]
    dec_ref[0, 0] = jnp.exp(cum[-1])
    cum_ref[0] = cum


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: jnp.ndarray,    # [G, Q, P]  (G = B*H, pre-multiplied by dt)
    da: jnp.ndarray,   # [G, Q]
    b: jnp.ndarray,    # [G, Q, N]  head-expanded
    c: jnp.ndarray,    # [G, Q, N]
    *,
    interpret: bool = True,
):
    """Returns (y_diag [G,Q,P], states [G,P,N], total_decay [G], cum [G,Q])."""
    g, q, p = x.shape
    n = b.shape[-1]
    y, st, dec, cum = pl.pallas_call(
        _chunk_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, q, p), jnp.float32),
            jax.ShapeDtypeStruct((g, p, n), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, q), jnp.float32),
        ],
        interpret=interpret,
    )(x, da, b, c)
    return y, st, dec[:, 0], cum


def _combine_kernel(c_ref, cum_ref, st_ref, y_ref):
    c = c_ref[0].astype(jnp.float32)          # [Q, N]
    cum = cum_ref[0].astype(jnp.float32)      # [Q]
    st = st_ref[0].astype(jnp.float32)        # [P, N]
    y_ref[0] = jnp.exp(cum)[:, None] * jnp.dot(
        c, st.T, preferred_element_type=jnp.float32
    )                                         # [Q, P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_combine_pallas(
    c: jnp.ndarray,         # [G, Q, N]
    cum: jnp.ndarray,       # [G, Q]
    states_in: jnp.ndarray, # [G, P, N]  (state entering each chunk)
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    g, q, n = c.shape
    p = states_in.shape[1]
    return pl.pallas_call(
        _combine_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, q, p), jnp.float32),
        interpret=interpret,
    )(c, cum, states_in)
