"""Decoder-only LM composition: dense / MoE / SSM / hybrid / VLM.

Layer stacking is organized in *groups* so heterogeneous cadences scan
cleanly with bounded HLO:

  dense, dbrx-moe, mamba2 : period R=1 (homogeneous stack)
  llama4 (moe_every=2)    : R=2 groups [dense-FFN layer, MoE layer]
  vlm (cross_attn_every=5): R=5 groups [4 plain layers, 1 layer w/ gated
                            image cross-attention]
  zamba2 (hybrid)         : unrolled Python loop (38 small Mamba blocks +
                            one *shared* attention block applied every 6;
                            weight sharing makes scan stacking pointless)

Group params are stacked on a leading group axis and consumed by
``lax.scan`` with optional per-group ``jax.checkpoint`` (remat).  KV /
recurrent caches mirror the same stacking, so decode scans (params, cache)
jointly.  The CE loss is computed in sequence chunks so [B, S, V] fp32
logits are never resident.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_decode,
    attention_full,
    cross_attention,
    cross_attention_cached,
    init_attn,
    init_cross_attn,
    precompute_cross_kv,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    Params,
    dense_init,
    embed_init,
    init_mlp,
    mlp_apply,
    rmsnorm,
)
from repro.models.moe import init_moe, moe_apply
from repro.models.ssm import init_mamba_state, init_mamba_block, mamba_block_apply
from repro.sharding.ctx import shard_hint

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "lm_init_cache",
    "lm_prefill",
    "lm_decode_step",
    "chunked_ce",
    "group_period",
]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _adt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def group_period(cfg: ModelConfig) -> int:
    if cfg.family == "moe":
        return max(cfg.moe_every, 1)
    if cfg.family == "vlm":
        return max(cfg.cross_attn_every, 1)
    return 1


# ==========================================================================
# init
# ==========================================================================
def _init_group(key, cfg: ModelConfig) -> Params:
    """Params for ONE group (un-stacked)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    pdt = _dt(cfg)
    r = group_period(cfg)
    ks = iter(jax.random.split(key, 4 * r + 4))
    g: Params = {}
    if cfg.family in ("ssm", "hybrid"):
        g["ln1"] = jnp.ones((d,), jnp.float32)
        g["mamba"] = init_mamba_block(next(ks), cfg, pdt)
        return g
    h_eff = cfg.n_heads + cfg.pad_heads  # padded heads divide TP (§Perf H3)
    for j in range(r):
        g[f"ln1_{j}"] = jnp.ones((d,), jnp.float32)
        g[f"attn_{j}"] = init_attn(next(ks), d, h_eff, cfg.n_kv_heads, hd, pdt)
        g[f"ln2_{j}"] = jnp.ones((d,), jnp.float32)
        is_moe = cfg.family == "moe" and j == r - 1
        if is_moe:
            g[f"moe_{j}"] = init_moe(
                next(ks), d, cfg.d_ff, cfg.n_experts, cfg.mlp, cfg.shared_expert, pdt
            )
        else:
            g[f"mlp_{j}"] = init_mlp(next(ks), d, cfg.d_ff, cfg.mlp, pdt)
        if cfg.family == "vlm" and j == r - 1:
            g[f"lnx_{j}"] = jnp.ones((d,), jnp.float32)
            g[f"xattn_{j}"] = init_cross_attn(
                next(ks), d, cfg.n_heads, cfg.n_kv_heads, hd, pdt, gated=True
            )
    return g


def init_lm(key, cfg: ModelConfig) -> Params:
    """Full parameter pytree.  Group params stacked on a leading axis."""
    r = group_period(cfg)
    if cfg.n_layers % r != 0:
        raise ValueError(f"n_layers {cfg.n_layers} not divisible by period {r}")
    n_groups = cfg.n_layers // r
    k_embed, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    pdt = _dt(cfg)
    params: Params = {
        "embed": embed_init(k_embed, (cfg.vocab, cfg.d_model), pdt),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), pdt)
    if cfg.family == "hybrid":  # unrolled stack + one shared attn block
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = [_init_group(keys[i], cfg) for i in range(cfg.n_layers)]
        sk = jax.random.split(k_shared, 2)
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attn(
                sk[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, pdt
            ),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(sk[1], cfg.d_model, cfg.d_ff, cfg.mlp, pdt),
        }
    else:
        keys = jax.random.split(k_blocks, n_groups)
        params["blocks"] = jax.vmap(lambda k: _init_group(k, cfg))(keys)
    if cfg.coded:
        from repro.core.coded_ops import encode_blocks

        head = params["lm_head"] if "lm_head" in params else params["embed"].T
        n_blocks = _coded_blocks(cfg)
        params["lm_head_coded"] = encode_blocks(
            head.T.astype(jnp.float32), n_blocks - cfg.coded_parity, cfg.coded_parity
        ).astype(pdt)
    return params


# ==========================================================================
# forward (train / prefill)
# ==========================================================================
def _apply_group_full(
    gp: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    img: jnp.ndarray | None,
    collect_kv: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, Params]:
    """One group, full-sequence mode.  Returns (x, aux_loss, kv_dict)."""
    r = group_period(cfg)
    aux = jnp.zeros((), jnp.float32)
    kv: Params = {}
    if cfg.family in ("ssm", "hybrid"):
        h, _ = mamba_block_apply(gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps))
        return x + h, aux, kv
    for j in range(r):
        h = rmsnorm(x, gp[f"ln1_{j}"], cfg.norm_eps)
        if collect_kv:
            dt = h.dtype
            k = jnp.einsum("bsd,dhk->bshk", h, gp[f"attn_{j}"]["w_k"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", h, gp[f"attn_{j}"]["w_v"].astype(dt))
            from repro.models.layers import apply_rope

            kv[f"attn_{j}"] = {"k": apply_rope(k, positions, cfg.rope_theta), "v": v}
        x = x + attention_full(gp[f"attn_{j}"], h, positions, cfg.rope_theta,
                               n_real=cfg.n_heads if cfg.pad_heads else None)
        if cfg.family == "vlm" and j == r - 1 and img is not None:
            hx = rmsnorm(x, gp[f"lnx_{j}"], cfg.norm_eps)
            x = x + cross_attention(gp[f"xattn_{j}"], hx, img)
        h2 = rmsnorm(x, gp[f"ln2_{j}"], cfg.norm_eps)
        if f"moe_{j}" in gp:
            y, a = moe_apply(
                gp[f"moe_{j}"],
                h2,
                top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                kind=cfg.mlp,
                dispatch_groups=cfg.moe_dispatch_groups,
            )
            aux = aux + a
        else:
            y = mlp_apply(gp[f"mlp_{j}"], h2, cfg.mlp)
        x = x + y
    return x, aux, kv


def _shared_attn_apply(sp: Params, cfg: ModelConfig, x, positions):
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    x = x + attention_full(sp["attn"], h, positions, cfg.rope_theta)
    h2 = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + mlp_apply(sp["mlp"], h2, cfg.mlp)


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,               # [B, S]
    img: jnp.ndarray | None = None,    # [B, n_img, D] (vlm stub frontend)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (final hidden [B,S,D] in activation dtype, moe aux loss)."""
    adt = _adt(cfg)
    x = params["embed"][tokens].astype(adt)
    x = shard_hint(x, "act_bsd")
    positions = jnp.arange(tokens.shape[1])[None, :]
    img = img.astype(adt) if img is not None else None

    if cfg.family == "hybrid":
        aux = jnp.zeros((), jnp.float32)
        for i, gp in enumerate(params["blocks"]):
            body = partial(_hybrid_layer, cfg=cfg, use_attn=(i + 1) % cfg.attn_every == 0)
            if cfg.remat:
                body = jax.checkpoint(body)
            x = body(gp, params["shared_attn"], x, positions)
        return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux

    def body(carry, gp):
        x, aux = carry
        x = shard_hint(x, "act_bsd")
        x, a, _ = _apply_group_full(gp, cfg, x, positions, img, collect_kv=False)
        return (x, aux + a), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def _hybrid_layer(gp, sp, x, positions, *, cfg: ModelConfig, use_attn: bool):
    h, _ = mamba_block_apply(gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps))
    x = x + h
    if use_attn:
        x = _shared_attn_apply(sp, cfg, x, positions)
    return x


# ==========================================================================
# loss (chunked cross-entropy — never materializes [B,S,V] fp32)
# ==========================================================================
def chunked_ce(
    hidden: jnp.ndarray,    # [B, S, D]
    head: jnp.ndarray,      # [D, V]
    labels: jnp.ndarray,    # [B, S] int32; -1 = padding (ignored)
    chunk: int,
    onehot_pick: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean token CE + token count, scanned over sequence chunks.

    ``onehot_pick``: gather the label logit as a one-hot contraction —
    with vocab-sharded logits a take_along_axis gather forces GSPMD to
    all-gather the full [B,c,V] logits, while the one-hot dot contracts
    over the sharded vocab axis locally + one tiny all-reduce (§Perf H1).
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    if s % c != 0:
        c = math.gcd(s, c) or s
    nc = s // c
    hc = hidden.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    v = head.shape[1]

    def step(carry, inp):
        tot, cnt = carry
        h, lab = inp
        logits = (h.astype(jnp.float32) @ head.astype(jnp.float32))  # [B,c,V]
        logits = shard_hint(logits, "logits_bsv")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        valid = lab >= 0
        if onehot_pick:
            hot = jax.nn.one_hot(jnp.clip(lab, 0), v, dtype=jnp.float32)
            pick = jnp.einsum("bcv,bcv->bc", logits, hot)
        else:
            pick = jnp.take_along_axis(
                logits, jnp.clip(lab, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - pick) * valid
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0), cnt


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    batch: dict[str, jnp.ndarray],
    aux_weight: float = 0.01,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """batch: tokens [B,S], labels [B,S] (+ img_embed for vlm)."""
    hidden, aux = lm_forward(params, cfg, batch["tokens"], batch.get("img_embed"))
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    ce, cnt = chunked_ce(hidden, head, batch["labels"], cfg.logit_chunk,
                         onehot_pick=cfg.onehot_ce)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


# ==========================================================================
# caches
# ==========================================================================
def lm_init_cache(cfg: ModelConfig, batch: int, s_max: int) -> Params:
    """Decode cache pytree (stacking mirrors params['blocks'])."""
    hd = cfg.resolved_head_dim
    r = group_period(cfg)
    kv_shape = (batch, s_max, cfg.n_kv_heads, hd)

    def kv():
        return {"k": jnp.zeros(kv_shape, jnp.bfloat16), "v": jnp.zeros(kv_shape, jnp.bfloat16)}

    cache: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "hybrid":
        cache["blocks"] = [
            {"mamba": init_mamba_state(cfg, batch)} for _ in range(cfg.n_layers)
        ]
        n_apps = cfg.n_layers // cfg.attn_every
        cache["shared_attn"] = {
            "k": jnp.zeros((n_apps,) + kv_shape, jnp.bfloat16),
            "v": jnp.zeros((n_apps,) + kv_shape, jnp.bfloat16),
        }
        return cache
    if cfg.family == "ssm":
        n_groups = cfg.n_layers
        st = init_mamba_state(cfg, batch)
        cache["blocks"] = {
            "mamba": jax.tree.map(lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), st)
        }
        return cache
    n_groups = cfg.n_layers // r
    g: Params = {}
    for j in range(r):
        g[f"attn_{j}"] = kv()
        if cfg.family == "vlm" and j == r - 1:
            g[f"xattn_{j}"] = {
                "ck": jnp.zeros((batch, cfg.img_tokens, cfg.n_kv_heads, hd), jnp.bfloat16),
                "cv": jnp.zeros((batch, cfg.img_tokens, cfg.n_kv_heads, hd), jnp.bfloat16),
            }
    cache["blocks"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), g
    )
    return cache


# ==========================================================================
# prefill
# ==========================================================================
def lm_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,               # [B, S]
    img: jnp.ndarray | None = None,
    s_max: int | None = None,          # cache capacity (>= S; default S)
    head_mask: jnp.ndarray | None = None,  # coded-head erasure mask [16]
) -> tuple[jnp.ndarray, Params]:
    """Full forward that also emits the KV/recurrent cache and the logits of
    the last position — the serving prefill step.  ``s_max`` reserves cache
    headroom for subsequent decode steps."""
    adt = _adt(cfg)
    b, s = tokens.shape
    s_max = s_max or s
    x = params["embed"][tokens].astype(adt)
    x = shard_hint(x, "act_bsd")
    positions = jnp.arange(s)[None, :]
    img = img.astype(adt) if img is not None else None
    cache: Params = {"pos": jnp.full((b,), s, jnp.int32)}

    if cfg.family == "hybrid":
        blocks_cache = []
        shared_k, shared_v = [], []
        napp = 0
        for i, gp in enumerate(params["blocks"]):
            h, st = mamba_block_apply(gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps))
            st["conv"] = _conv_tail(cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps), gp["mamba"])
            x = x + h
            blocks_cache.append({"mamba": st})
            if (i + 1) % cfg.attn_every == 0:
                sp = params["shared_attn"]
                hh = rmsnorm(x, sp["ln1"], cfg.norm_eps)
                from repro.models.layers import apply_rope

                k = jnp.einsum("bsd,dhk->bshk", hh, sp["attn"]["w_k"].astype(adt))
                v = jnp.einsum("bsd,dhk->bshk", hh, sp["attn"]["w_v"].astype(adt))
                shared_k.append(apply_rope(k, positions, cfg.rope_theta))
                shared_v.append(v)
                x = _shared_attn_apply(sp, cfg, x, positions)
                napp += 1
        cache["blocks"] = blocks_cache
        cache["shared_attn"] = _pad_cache_seq(
            {
                "k": jnp.stack(shared_k).astype(jnp.bfloat16),
                "v": jnp.stack(shared_v).astype(jnp.bfloat16),
            },
            s,
            s_max,
        )
        hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _last_logits(params, hidden, cfg, head_mask), cache

    if cfg.family == "ssm":

        def body(x, gp):
            h, st = mamba_block_apply(gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps))
            st["conv"] = _conv_tail(cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps), gp["mamba"])
            return x + h, {"mamba": st}

        x, states = jax.lax.scan(body, x, params["blocks"])
        cache["blocks"] = states
        hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _last_logits(params, hidden, cfg, head_mask), cache

    def body(carry, gp):
        x = carry
        x, _, kvd = _apply_group_full(gp, cfg, x, positions, img, collect_kv=True)
        if cfg.family == "vlm":
            r = group_period(cfg)
            ck, cv = precompute_cross_kv(gp[f"xattn_{r-1}"], img)
            kvd[f"xattn_{r-1}"] = {"ck": ck.astype(jnp.bfloat16), "cv": cv.astype(jnp.bfloat16)}
        kvd = jax.tree.map(lambda t: t.astype(jnp.bfloat16), kvd)
        return x, kvd

    x, kvs = jax.lax.scan(body, x, params["blocks"])
    # normalize cache key layout: {"attn_j": {"k","v"}} stacked on groups
    cache["blocks"] = _pad_cache_seq(kvs, s, s_max)
    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _last_logits(params, hidden, cfg, head_mask), cache


def _pad_cache_seq(tree: Params, s: int, s_max: int) -> Params:
    """Pad self-attention cache K/V (leaf names 'k'/'v') from S to s_max on
    the sequence axis (-3), leaving cross-attention ck/cv untouched."""
    if s_max <= s:
        return tree

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[-3] = (0, s_max - s)
            return jnp.pad(x, cfgpad)
        return x

    return jax.tree_util.tree_map_with_path(pad, tree)


def _conv_tail(cfg: ModelConfig, u: jnp.ndarray, mp: Params) -> jnp.ndarray:
    """Last (W-1) conv inputs after prefill — the decode conv cache."""
    zxbcdt = u @ mp["in_proj"].astype(u.dtype)
    din, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    xbc = zxbcdt[..., din : 2 * din + 2 * g * n]
    w = cfg.conv_width
    return xbc[:, -(w - 1) :].astype(jnp.bfloat16)


def _last_logits(
    params: Params,
    hidden: jnp.ndarray,
    cfg: ModelConfig | None = None,
    head_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Last-position logits.  With ``cfg.coded`` the head matvec runs through
    the BPCC CodedLinear blocks: any ``coded_parity`` erased model-shards
    (``head_mask`` zeros) still yield exact logits — the paper's
    straggler-tolerant matrix-vector product as the serving hot path.

    Inside a ``sharding.ctx.coded_head_mesh`` context the same matvec runs
    shard_map'd over a real mesh — one code block per device, erasure =
    dropping a device's output — via ``kernels.ops.coded_head_matvec``
    (bit-identical to the single-program path on identical masks).  A
    ``sharding.ctx.head_kernel_mode`` context picks the head's kernel
    implementation — ``'auto'`` for the autotuned per-shape dispatch
    (DESIGN.md §11), resolved here at trace time from the static shapes."""
    last = hidden[:, -1]
    if cfg is not None and cfg.coded and "lm_head_coded" in params:
        from repro.kernels.ops import coded_head_matvec
        from repro.sharding.ctx import (
            current_coded_head_mesh,
            current_head_kernel_mode,
        )

        n_blocks = _coded_blocks(cfg)
        mask = head_mask if head_mask is not None else jnp.ones((n_blocks,), jnp.float32)
        cm = current_coded_head_mesh()
        mesh, axis = cm if cm is not None else (None, "model")
        y = coded_head_matvec(
            params["lm_head_coded"].astype(jnp.float32),
            last.astype(jnp.float32).T,
            mask,
            n_blocks - cfg.coded_parity,
            cfg.coded_parity,
            mesh=mesh,
            axis=axis,
            kernel_mode=current_head_kernel_mode(),
        )
        return y[: cfg.vocab].T
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return last.astype(jnp.float32) @ head.astype(jnp.float32)


def _coded_blocks(cfg: ModelConfig) -> int:
    """Total coded blocks for the serving head = TP width (one per shard)."""
    from repro.models.config import coded_blocks

    return coded_blocks(cfg)


# ==========================================================================
# decode step
# ==========================================================================
def lm_decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    tokens: jnp.ndarray,  # [B] — one new token per sequence
    head_mask: jnp.ndarray | None = None,  # coded-head erasure mask [16]
) -> tuple[jnp.ndarray, Params]:
    """One decoding step: returns (logits [B, vocab] fp32, updated cache)."""
    adt = _adt(cfg)
    pos = cache["pos"]
    x = params["embed"][tokens][:, None].astype(adt)  # [B,1,D]
    x = shard_hint(x, "act_bsd")

    if cfg.family == "hybrid":
        new_blocks = []
        app = 0
        for i, gp in enumerate(params["blocks"]):
            st = cache["blocks"][i]["mamba"]
            h, st2 = mamba_block_apply(
                gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps), state=st
            )
            x = x + h
            new_blocks.append({"mamba": st2})
            if (i + 1) % cfg.attn_every == 0:
                x, cache = _shared_attn_decode(params, cfg, cache, x, pos, app)
                app += 1
        new_cache = dict(cache)
        new_cache["blocks"] = new_blocks
        new_cache["pos"] = pos + 1
        hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _last_logits(params, hidden, cfg, head_mask), new_cache

    if cfg.family == "ssm":

        def body(x, inp):
            gp, st = inp
            h, st2 = mamba_block_apply(
                gp["mamba"], cfg, rmsnorm(x, gp["ln1"], cfg.norm_eps), state=st["mamba"]
            )
            return x + h, {"mamba": st2}

        x, states = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"pos": pos + 1, "blocks": states}
        hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _last_logits(params, hidden, cfg, head_mask), new_cache

    r = group_period(cfg)

    def body(x, inp):
        gp, cg = inp
        new_cg = dict(cg)
        for j in range(r):
            h = rmsnorm(x, gp[f"ln1_{j}"], cfg.norm_eps)
            out, nk, nv = attention_decode(
                gp[f"attn_{j}"], h, cg[f"attn_{j}"]["k"], cg[f"attn_{j}"]["v"], pos,
                cfg.rope_theta,
                n_real=cfg.n_heads if cfg.pad_heads else None,
                aligned=cfg.aligned_decode,
            )
            new_cg[f"attn_{j}"] = {"k": nk, "v": nv}
            x = x + out
            if cfg.family == "vlm" and j == r - 1:
                hx = rmsnorm(x, gp[f"lnx_{j}"], cfg.norm_eps)
                x = x + cross_attention_cached(
                    gp[f"xattn_{j}"], hx, cg[f"xattn_{j}"]["ck"], cg[f"xattn_{j}"]["cv"]
                )
            h2 = rmsnorm(x, gp[f"ln2_{j}"], cfg.norm_eps)
            if f"moe_{j}" in gp:
                y, _ = moe_apply(
                    gp[f"moe_{j}"], h2,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, kind=cfg.mlp,
                    dispatch_groups=cfg.moe_dispatch_groups,
                )
            else:
                y = mlp_apply(gp[f"mlp_{j}"], h2, cfg.mlp)
            x = x + y
        return x, new_cg

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    new_cache = {"pos": pos + 1, "blocks": new_blocks}
    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _last_logits(params, hidden, cfg, head_mask), new_cache


def _shared_attn_decode(params, cfg, cache, x, pos, app: int):
    """Apply the zamba2 shared attention block at decode with its own cache
    slice (weights shared; caches per application)."""
    sp = params["shared_attn"]
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    ck = cache["shared_attn"]["k"][app]
    cv = cache["shared_attn"]["v"][app]
    out, nk, nv = attention_decode(sp["attn"], h, ck, cv, pos, cfg.rope_theta)
    new_cache = dict(cache)
    new_cache["shared_attn"] = {
        "k": cache["shared_attn"]["k"].at[app].set(nk),
        "v": cache["shared_attn"]["v"].at[app].set(nv),
    }
    x = x + out
    h2 = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + mlp_apply(sp["mlp"], h2, cfg.mlp), new_cache
