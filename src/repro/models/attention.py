"""Grouped-query attention: full (train/prefill), decode (KV cache), cross.

Layout convention: activations [B, S, D]; per-head tensors [B, S, H, Hd];
KV caches [B, S_max, KVH, Hd].  Softmax in fp32.  TP shards the head axis
(uneven head counts are allowed — GSPMD pads; see DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -1e30


def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "w_q": dense_init(kq, (d_model, n_heads, head_dim), dtype, fan_in=d_model),
        "w_k": dense_init(kk, (d_model, n_kv, head_dim), dtype, fan_in=d_model),
        "w_v": dense_init(kv, (d_model, n_kv, head_dim), dtype, fan_in=d_model),
        "w_o": dense_init(ko, (n_heads, head_dim, d_model), dtype, fan_in=n_heads * head_dim),
    }


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """q [B,Sq,H,Hd], k/v [B,Sk,KVH,Hd], mask [B,1,1,Sq,Sk] or None."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    gs = h // kvh  # query heads per kv head
    q = q.reshape(b, sq, kvh, gs, hd)
    logits = jnp.einsum("bqgmd,bkgd->bgmqk", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgmqk,bkgd->bqgmd", probs, v)
    return out.reshape(b, sq, h, hd)


# threshold above which the S² logits tensor must not materialize
CHUNKED_ATTN_THRESHOLD = 8192


def _sdpa_chunked(
    q, k, v, *, causal: bool, q_chunk: int = 1024, kv_chunk: int = 2048
) -> jnp.ndarray:
    """Flash-style online-softmax SDPA: never materializes [Sq, Sk] logits.

    Outer ``lax.map`` over query chunks; inner ``lax.scan`` over KV chunks
    carrying (running max, denominator, weighted accumulator).  Causal
    chunks beyond the diagonal are masked (not skipped): fixed shapes keep
    XLA happy at the cost of <=2x attention FLOPs versus a triangular
    schedule — recorded as a §Perf candidate.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    gs = h // kvh
    qc, kc = min(q_chunk, sq), min(kv_chunk, sk)
    if sq % qc or sk % kc:
        return _sdpa(q, k, v, _causal_mask5(sq, sk) if causal else None)
    nq, nk = sq // qc, sk // kc
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qr = q.reshape(b, nq, qc, kvh, gs, hd).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)

    def per_q_chunk(args):
        qi, qblk = args  # [B,qc,KVH,gs,Hd]
        qpos = qi * qc + jnp.arange(qc)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, kblk, vblk = inp
            logits = jnp.einsum(
                "bqgmd,bkgd->bqgmk", qblk.astype(jnp.float32),
                kblk.astype(jnp.float32)) * scale  # [B,qc,KVH,gs,kc]
            if causal:
                kpos = kj * kc + jnp.arange(kc)
                msk = (kpos[None, :] <= qpos[:, None])[None, :, None, None, :]
                logits = jnp.where(msk, logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqgmk,bkgd->bqgmd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, qc, kvh, gs), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qc, kvh, gs), jnp.float32)
        a0 = jnp.zeros((b, qc, kvh, gs, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(per_q_chunk, (jnp.arange(nq), qr))  # [nq,B,qc,KVH,gs,Hd]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out.astype(v.dtype)


def _causal_mask5(sq: int, sk: int) -> jnp.ndarray:
    return (jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None])[None, None, None]


def _mask_pad_heads(out: jnp.ndarray, n_real: int | None) -> jnp.ndarray:
    """Zero the outputs of padding heads (cfg.pad_heads): the function and
    its gradients then equal the unpadded model exactly — pad w_q/w_o slices
    receive zero gradient and stay inert, while the head axis divides TP."""
    if n_real is None or n_real >= out.shape[2]:
        return out
    mask = (jnp.arange(out.shape[2]) < n_real).astype(out.dtype)
    return out * mask[None, None, :, None]


def attention_full(
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    *,
    causal: bool = True,
    n_real: int | None = None,
) -> jnp.ndarray:
    """Full self-attention over [B, S, D] (training / prefill)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"].astype(dt))
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    s = x.shape[1]
    if s >= CHUNKED_ATTN_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal=causal)
    else:
        mask = _causal_mask5(s, s) if causal else None
        out = _sdpa(q, k, v, mask)
    out = _mask_pad_heads(out, n_real)
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"].astype(dt))


def attention_decode(
    p: Params,
    x: jnp.ndarray,            # [B, 1, D] — one new token per sequence
    cache_k: jnp.ndarray,      # [B, S_max, KVH, Hd]
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,          # [B] int — write/attend position per sequence
    theta: float,
    n_real: int | None = None,
    aligned: bool = False,     # all sequences share one position (batch-
    #   aligned decoding): O(1)-token dynamic_update_slice instead of the
    #   masked full-cache rewrite (§Perf: halves decode cache traffic)
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step; returns (out [B,1,D], new_k, new_v)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"].astype(dt))
    q = apply_rope(q, pos[:, None], theta)
    k = apply_rope(k, pos[:, None], theta)

    s_max = cache_k.shape[1]
    if aligned:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos[0], axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos[0], axis=1)
    else:
        # masked one-hot write (NOT vmapped dynamic_update_slice): per-seq
        # scatter positions make the SPMD partitioner fall into pathological
        # resharding when the cache's sequence dim is sharded — the
        # elementwise select shards trivially at the cost of rewriting the
        # cache (decode already reads it; ~1.5x traffic, charged honestly)
        hot = (jnp.arange(s_max)[None, :] == pos[:, None])[..., None, None]
        cache_k = jnp.where(hot, k[:, 0][:, None].astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(hot, v[:, 0][:, None].astype(cache_v.dtype), cache_v)
    mask = (jnp.arange(s_max)[None, :] <= pos[:, None])[:, None, None, None, :]
    out = _sdpa(q, cache_k.astype(dt), cache_v.astype(dt), mask)
    out = _mask_pad_heads(out, n_real)
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"].astype(dt)), cache_k, cache_v


# --------------------------------------------------------------------------
# Cross-attention (VLM image layers, enc-dec decoder)
# --------------------------------------------------------------------------
def init_cross_attn(
    key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype, gated: bool = False
) -> Params:
    p = init_attn(key, d_model, n_heads, n_kv, head_dim, dtype)
    if gated:
        p["gate"] = jnp.zeros((), jnp.float32)  # tanh-gated (llama-vision style)
    return p


def cross_attention(p: Params, x: jnp.ndarray, memory: jnp.ndarray) -> jnp.ndarray:
    """x [B,Sq,D] attends over memory [B,Sk,D] (no RoPE, no causal mask)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", memory.astype(dt), p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory.astype(dt), p["w_v"].astype(dt))
    if max(x.shape[1], memory.shape[1]) >= CHUNKED_ATTN_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal=False)
    else:
        out = _sdpa(q, k, v, None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["w_o"].astype(dt))
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(dt) * y
    return y


def precompute_cross_kv(p: Params, memory: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cache the cross-attention K/V once per request (decode fast path)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["w_k"].astype(memory.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["w_v"].astype(memory.dtype))
    return k, v


def cross_attention_cached(
    p: Params, x: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray
) -> jnp.ndarray:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    out = _sdpa(q, k.astype(dt), v.astype(dt), None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["w_o"].astype(dt))
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(dt) * y
    return y
