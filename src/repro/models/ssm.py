"""Mamba-2 (SSD — state-space duality) blocks.

The chunked SSD algorithm from Dao & Gu (arXiv:2405.21060): sequence split
into chunks of length Q; within-chunk terms are plain matmuls (MXU-friendly
— this is the part the Pallas kernel ``repro.kernels.ssd_scan`` tiles), and
the cross-chunk term is a short ``lax.scan`` recurrence over running states
[H, P, N].  Decode is the O(1) recurrent update — what makes the
``long_500k`` cells runnable for the ssm/hybrid archs while full-attention
archs are skipped.

Structure per block (faithful to the reference implementation, biases
omitted — noted in DESIGN.md):
  in_proj -> [z | xBC | dt], causal depthwise conv(width w) on xBC, silu,
  SSD over heads (A scalar/head, B/C grouped), +D skip, gate by silu(z),
  RMSNorm, out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, rmsnorm
from repro.sharding.ctx import shard_hint

NEG_INF = -1e30


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """[..., Q] -> [..., Q, Q]: sum_{k=j+1..i} x_k for i >= j, -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(
    x: jnp.ndarray,    # [B, S, H, P]   (pre-multiplied by dt)
    da: jnp.ndarray,   # [B, S, H]      (dt * A, negative)
    b_: jnp.ndarray,   # [B, S, G, N]
    c_: jnp.ndarray,   # [B, S, G, N]
    chunk: int,
    h0: jnp.ndarray | None = None,  # [B, H, P, N] initial state
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD; returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s_orig, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    q = min(chunk, s_orig)
    if s_orig % q != 0:
        # pad to a chunk multiple: dt=0 at padding -> decay exp(0)=1 and zero
        # state contribution, so the final state is untouched by pad tokens
        pad = q - s_orig % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = x.shape[1]
    nc = s // q
    rep = h // g  # heads per group

    xc = x.reshape(bsz, nc, q, h, p)
    dac = da.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)       # [B, H, nc, Q]
    bc = b_.reshape(bsz, nc, q, g, n)
    cc = c_.reshape(bsz, nc, q, g, n)
    bh = jnp.repeat(bc, rep, axis=3)                             # [B,nc,Q,H,N]
    ch = jnp.repeat(cc, rep, axis=3)

    da_cum = jnp.cumsum(dac, axis=-1)                            # [B,H,nc,Q]
    # ---- intra-chunk (quadratic in Q — matmul form; Pallas target) -----
    ell = jnp.exp(_segsum(dac.astype(jnp.float32)))              # [B,H,nc,Q,Q]
    cb = jnp.einsum("bclhn,bcshn->bhcls", ch, bh)                # [B,H,nc,Q,Q]
    y_diag = jnp.einsum(
        "bhcls,bhcls,bcshp->bclhp", cb.astype(jnp.float32), ell, xc.astype(jnp.float32)
    )

    # ---- chunk states ---------------------------------------------------
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)            # [B,H,nc,Q]
    states = jnp.einsum(
        "bcshn,bhcs,bcshp->bchpn",
        bh.astype(jnp.float32),
        decay_states,
        xc.astype(jnp.float32),
    )                                                            # [B,nc,H,P,N]

    # ---- inter-chunk recurrence (sequential scan over chunks) -----------
    total_decay = jnp.exp(da_cum[..., -1])                       # [B,H,nc]
    init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp                                            # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                        # emit state *entering* chunk

    final, states_in = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), total_decay.transpose(2, 0, 1)),
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)               # [B,nc,H,P,N]

    # ---- inter-chunk output ---------------------------------------------
    out_decay = jnp.exp(da_cum)                                  # [B,H,nc,Q]
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", ch.astype(jnp.float32), states_in, out_decay
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jnp.ndarray,  # [B, H, P, N] fp32
    x: jnp.ndarray,      # [B, H, P]   (pre-multiplied by dt)
    da: jnp.ndarray,     # [B, H]      (dt * A)
    b_: jnp.ndarray,     # [B, G, N]
    c_: jnp.ndarray,     # [B, G, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent update; returns (y [B,H,P], new_state)."""
    h = x.shape[1]
    g = b_.shape[1]
    rep = h // g
    bh = jnp.repeat(b_, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    ch = jnp.repeat(c_, rep, axis=1).astype(jnp.float32)
    new = state * jnp.exp(da.astype(jnp.float32))[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32), bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", new, ch)
    return y.astype(x.dtype), new


# --------------------------------------------------------------------------
# Mamba-2 block
# --------------------------------------------------------------------------
def init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    conv_ch = din + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * g * n + h), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_ch), dtype, fan_in=cfg.conv_width),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[2], (din, d), dtype, fan_in=din),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, cache: jnp.ndarray | None):
    """Depthwise causal conv1d.  xbc [B,S,C], w [W,C]; cache [B,W-1,C] for
    decode (returns updated cache)."""
    width = w.shape[0]
    if cache is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
        full = jnp.concatenate([pad, xbc], axis=1)
        new_cache = None
    else:
        full = jnp.concatenate([cache.astype(xbc.dtype), xbc], axis=1)
        new_cache = full[:, -(width - 1) :]
    out = sum(
        full[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(width)
    )
    return jax.nn.silu(out), new_cache


def _split_in_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    return z, xbc, dt


def mamba_block_apply(
    p: Params, cfg: ModelConfig, u: jnp.ndarray, state: dict | None = None
):
    """u [B,S,D] -> y [B,S,D].  With ``state`` (dict: ssm [B,H,P,N] fp32,
    conv [B,W-1,C]) runs in decode mode (S==1) and returns (y, new_state);
    otherwise returns (y, final_state_dict)."""
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim
    dt_ = u.dtype

    zxbcdt = u @ p["in_proj"].astype(dt_)
    z, xbc, dtv = _split_in_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], None if state is None else state["conv"])
    x, b_, c_ = jnp.split(xbc, [din, din + g * n], axis=-1)
    x = shard_hint(x.reshape(*x.shape[:-1], h, pdim), "act_bshp")
    b_ = b_.reshape(*b_.shape[:-1], g, n)
    c_ = c_.reshape(*c_.shape[:-1], g, n)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"])                                        # [H]
    xdt = x * dtv[..., None].astype(dt_)
    da = dtv * a

    if state is None:
        y, final = ssd_chunked(xdt, da, b_, c_, cfg.ssm_chunk)
        new_state = {"ssm": final, "conv": None}
    else:
        y1, new_ssm = ssd_decode_step(
            state["ssm"], xdt[:, 0], da[:, 0], b_[:, 0], c_[:, 0]
        )
        y = y1[:, None]
        new_state = {"ssm": new_ssm, "conv": new_conv}

    y = y + x * p["d_skip"][:, None].astype(dt_)
    y = y.reshape(*y.shape[:-2], din)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    """Decode-time recurrent state for ONE block (stacked by the caller)."""
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), jnp.bfloat16),
    }
