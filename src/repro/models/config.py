"""Model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / SSM / hybrid / VLM / enc-dec
LMs; the registry (``repro.models.registry``) dispatches on ``family``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["ModelConfig", "coded_blocks"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int              # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0         # 0 -> d_model // n_heads
    mlp: str = "swiglu"       # swiglu | relu2 | gelu
    rope_theta: float = 5e5
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1        # every k-th layer is MoE (1 = all layers)
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4: dense "shared expert" beside routed

    # --- SSM (Mamba-2 SSD) --------------------------------------------
    ssm_state: int = 0        # N
    ssm_expand: int = 2       # d_inner = expand * d_model
    ssm_head_dim: int = 64    # P; n_ssm_heads = d_inner // P
    ssm_groups: int = 1       # G (B/C groups)
    ssm_chunk: int = 256      # SSD chunk length
    conv_width: int = 4

    # --- hybrid (zamba2): shared attention block cadence ---------------
    attn_every: int = 0       # shared attn+MLP block after every k SSM layers

    # --- VLM (llama-3.2-vision): gated cross-attn cadence --------------
    cross_attn_every: int = 0  # every k-th layer gets image cross-attention
    img_tokens: int = 1024     # stub frontend: precomputed patch embeddings

    # --- enc-dec (seamless): encoder depth; n_layers = decoder depth ----
    enc_layers: int = 0
    frame_tokens: int = 0      # stub speech frontend: precomputed frames/step

    # --- numerics / execution -----------------------------------------
    param_dtype: str = "float32"
    dtype: str = "bfloat16"   # activation/compute dtype
    remat: bool = True        # per-layer activation checkpointing in scan
    logit_chunk: int = 1024   # CE loss sequence chunking

    # --- coded-computation integration (the paper's technique) ---------
    coded: bool = False       # CodedLinear on decode-path projections
    coded_parity: int = 2     # parity blocks per coded projection

    # --- perf knobs (§Perf hillclimb; defaults = baseline) ---------------
    onehot_ce: bool = False   # CE label-pick as one-hot dot (vs take_along_axis
    #   which all-gathers vocab-sharded logits)
    pad_heads: int = 0        # pad attn heads to divide TP; pad outputs are
    #   masked so the function (and grads) equal the unpadded model exactly
    moe_dispatch_groups: int = 1  # shard-local MoE capacity/cumsum groups
    #   (breaks the cross-shard sequential cumsum chain)
    aligned_decode: bool = False  # batch-aligned decode positions: O(1)-token
    #   cache write (vs masked full-cache rewrite for ragged positions)

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "encdec"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "moe" and (self.n_experts < 2 or self.top_k < 1):
            raise ValueError("moe family needs n_experts >= 2 and top_k >= 1")
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError("ssm/hybrid family needs ssm_state > 0")
        if self.family == "encdec" and self.enc_layers <= 0:
            raise ValueError("encdec family needs enc_layers > 0")
        if self.pad_heads and self.n_kv_heads:
            if (self.n_heads + self.pad_heads) % self.n_kv_heads != 0:
                raise ValueError(
                    "padded head count must stay a multiple of n_kv_heads "
                    f"(got {self.n_heads}+{self.pad_heads} vs kv={self.n_kv_heads})"
                )

    # ---- derived sizes ----------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM/hybrid O(1)-state or
        O(S)-per-step paths only; pure full-attention archs are skipped.)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing (no encoder-only)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/topology, tiny sizes)."""
        return replace(self, **overrides)

    # ---- parameter count (analytic; used for roofline MODEL_FLOPS) ----
    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params) — active differs for MoE."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        mlp_dense = d * f * (3 if self.mlp == "swiglu" else 2)
        embed = v * d * (1 if self.tie_embeddings else 2)

        def ssm_layer() -> int:
            din, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.n_ssm_heads
            in_p = d * (2 * din + 2 * g * n + h)
            conv = (din + 2 * g * n) * self.conv_width
            out_p = din * d
            return in_p + conv + out_p + din + 2 * h  # +gate-norm, dt_bias, A_log

        total = active = embed
        if self.family in ("dense",):
            total += self.n_layers * (attn + mlp_dense)
            active = total
        elif self.family == "moe":
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            moe_l = self.n_experts * mlp_dense + d * self.n_experts
            if self.shared_expert:
                moe_l += mlp_dense
            total += self.n_layers * attn + n_dense * mlp_dense + n_moe * moe_l
            act_moe = self.top_k * mlp_dense + d * self.n_experts
            if self.shared_expert:
                act_moe += mlp_dense
            active = embed + self.n_layers * attn + n_dense * mlp_dense + n_moe * act_moe
        elif self.family == "ssm":
            total += self.n_layers * ssm_layer()
            active = total
        elif self.family == "hybrid":
            total += self.n_layers * ssm_layer() + (attn + mlp_dense)  # shared block
            active = total
        elif self.family == "vlm":
            n_cross = self.n_layers // max(self.cross_attn_every, 1) if self.cross_attn_every else 0
            cross = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
            total += self.n_layers * (attn + mlp_dense) + n_cross * cross
            active = total
        elif self.family == "encdec":
            total += self.enc_layers * (attn + mlp_dense)
            total += self.n_layers * (2 * attn + mlp_dense)  # self + cross
            active = total
        return int(total), int(active)


def coded_blocks(cfg: ModelConfig) -> int:
    """Total coded blocks for the serving head = TP width (one per shard).

    Lives here (jax-free) so launchers can resolve the coded-head geometry
    — e.g. for ``--dry-run`` config printing — without importing the model
    stack.
    """
    del cfg
    return 16
