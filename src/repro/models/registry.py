"""Model facade: one object per architecture with a uniform API.

    model = build_model(cfg)
    params = model.init(key)                       # real arrays (smoke tests)
    shapes = model.param_shapes()                  # ShapeDtypeStructs (dry-run)
    loss, metrics = model.loss(params, batch)      # train objective
    logits, cache = model.prefill(params, batch)   # serving prefill
    logits, cache = model.decode_step(params, cache, tokens)

``input_specs(kind, ...)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — which is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.models.config import ModelConfig

__all__ = ["Model", "build_model"]

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters ---------------------------------------------------
    def init(self, key) -> Any:
        if self.cfg.family == "encdec":
            return ed.init_encdec(key, self.cfg)
        return tf.init_lm(key, self.cfg)

    def param_shapes(self) -> Any:
        return jax.eval_shape(self.init, jax.random.key(0))

    # ---- training -------------------------------------------------------
    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        if self.cfg.family == "encdec":
            return ed.encdec_loss(params, self.cfg, batch)
        return tf.lm_loss(params, self.cfg, batch)

    def forward(self, params, tokens, **kw):
        if self.cfg.family == "encdec":
            return ed.encdec_forward(params, self.cfg, kw["frames"], tokens)
        return tf.lm_forward(params, self.cfg, tokens, kw.get("img"))[0]

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch: int, s_max: int) -> Any:
        if self.cfg.family == "encdec":
            return ed.encdec_init_cache(self.cfg, batch, s_max, s_src=s_max)
        return tf.lm_init_cache(self.cfg, batch, s_max)

    def cache_shapes(self, batch: int, s_max: int) -> Any:
        return jax.eval_shape(lambda: self.init_cache(batch, s_max))

    def prefill(self, params, batch: dict, s_max: int | None = None) -> tuple[jnp.ndarray, Any]:
        if self.cfg.family == "encdec":
            return ed.encdec_prefill(
                params, self.cfg, batch["frames"], batch["tokens"], s_max=s_max
            )
        return tf.lm_prefill(
            params, self.cfg, batch["tokens"], batch.get("img_embed"), s_max=s_max
        )

    def decode_step(self, params, cache, tokens, head_mask=None) -> tuple[jnp.ndarray, Any]:
        if self.cfg.family == "encdec":
            return ed.encdec_decode_step(params, self.cfg, cache, tokens)
        return tf.lm_decode_step(params, self.cfg, cache, tokens, head_mask=head_mask)

    # ---- dry-run input stand-ins -----------------------------------------
    def input_specs(self, kind: str, batch: int, seq: int) -> dict[str, Any]:
        """ShapeDtypeStructs for every input of the given step kind.

        kind: 'train' (tokens+labels), 'prefill' (tokens), 'decode' (one
        token per sequence; pair with ``cache_shapes(batch, seq)``).
        """
        cfg = self.cfg
        tok = jnp.int32
        d = cfg.d_model
        if kind == "train":
            spec = {"tokens": SDS((batch, seq), tok), "labels": SDS((batch, seq), tok)}
            if cfg.family == "vlm":
                spec["img_embed"] = SDS((batch, cfg.img_tokens, d), jnp.bfloat16)
            if cfg.family == "encdec":
                spec["frames"] = SDS((batch, seq, d), jnp.bfloat16)
            return spec
        if kind == "prefill":
            spec = {"tokens": SDS((batch, seq), tok)}
            if cfg.family == "vlm":
                spec["img_embed"] = SDS((batch, cfg.img_tokens, d), jnp.bfloat16)
            if cfg.family == "encdec":
                spec["frames"] = SDS((batch, seq, d), jnp.bfloat16)
            return spec
        if kind == "decode":
            return {"tokens": SDS((batch,), tok)}
        raise ValueError(f"unknown step kind {kind!r}")


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
