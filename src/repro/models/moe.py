"""Mixture-of-Experts with capacity-based scatter/gather dispatch.

Dispatch never materializes the [T, E, C] one-hot tensor (Switch-style
einsum dispatch is O(T·E·C) memory — 40 TB for the prefill_32k cells).
Instead:

  1. top-k routing (softmax over expert logits, renormalized top-k gates),
  2. position-in-expert by cumsum over the flattened (T·k) assignments,
     per-shard capacity C = ceil(cf · k · T / E),
  3. scatter tokens into a [E·C+1, D] buffer (overflow slot E·C collects
     capacity-dropped tokens and is discarded),
  4. batched expert GEMM [E, C, D] x [E, D, F]  — experts sharded over the
     `model` mesh axis (EP); XLA turns the scatter/gather into the
     all-to-all exchange,
  5. gather + gate-weighted combine back to [T, D].

FLOPs scale with E·C ≈ cf·k·T — the *active* compute, preserving the MoE
economics that make llama4-400b run like a 17B (roofline checks this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, init_mlp, mlp_apply
from repro.sharding.ctx import shard_hint


def init_moe(
    key, d_model: int, d_ff: int, n_experts: int, kind: str, shared: bool, dtype
) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    n_mats = 3 if kind == "swiglu" else 2
    kmats = jax.random.split(ke, n_mats)
    p: Params = {
        "router": dense_init(kr, (d_model, n_experts), jnp.float32),
        "w_up": dense_init(kmats[0], (n_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_down": dense_init(kmats[1], (n_experts, d_ff, d_model), dtype, fan_in=d_ff),
    }
    if kind == "swiglu":
        p["w_gate"] = dense_init(kmats[2], (n_experts, d_model, d_ff), dtype, fan_in=d_model)
    if shared:
        p["shared"] = init_mlp(ks, d_model, d_ff, kind, dtype)
    return p


def moe_apply(
    p: Params,
    x: jnp.ndarray,          # [B, S, D]
    *,
    top_k: int,
    capacity_factor: float,
    kind: str,
    dispatch_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,D], aux_loss scalar — load-balancing loss).

    ``dispatch_groups`` = G > 1 computes position-in-expert with G
    independent cumsums over token groups (capacity C/G each).  With G =
    the DP shard count and batch-major token order, each cumsum is
    shard-local — a global cumsum over a sharded token axis otherwise
    lowers to a sequential cross-shard collective-permute chain (§Perf H5).
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xf = x.reshape(t, d)
    g_ = dispatch_groups if (t * top_k) % dispatch_groups == 0 else 1

    # ---- routing (fp32) -------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)            # [T, k]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- capacity + position-in-expert (per dispatch group) --------------
    cap = int(max(1, -(-int(capacity_factor * top_k * t) // (e * g_))))  # ceil
    ids_f = ids.reshape(-1)                              # [T*k] expert per slot
    gates_f = gates.reshape(-1)
    tg = (t * top_k) // g_
    onehot = jax.nn.one_hot(ids_f.reshape(g_, tg), e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1                 # [G, tg, E] local count
    pos = jnp.take_along_axis(
        pos, ids_f.reshape(g_, tg)[..., None], axis=2)[..., 0].reshape(-1)
    keep = pos < cap
    grp = jnp.arange(t * top_k) // tg                    # group of each slot
    slot = jnp.where(keep, (ids_f * g_ + grp) * cap + pos, e * g_ * cap)

    # ---- dispatch: scatter to [E*G*C (+1 overflow), D] --------------------
    xrep = jnp.repeat(xf, top_k, axis=0) if top_k > 1 else xf  # [T*k, D]
    buf = jnp.zeros((e * g_ * cap + 1, d), x.dtype).at[slot].add(xrep)
    h = buf[: e * g_ * cap].reshape(e, g_ * cap, d)
    h = shard_hint(h, "moe_ecd")
    cap = g_ * cap  # expert GEMM sees the concatenated group buffers

    # ---- batched expert FFN ---------------------------------------------
    dt = x.dtype
    if kind == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(dt))
        u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(dt))
        act = jax.nn.silu(g) * u
    elif kind == "relu2":
        act = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(dt))))
    else:
        act = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(dt)))
    y_exp = jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(dt))
    y_exp = shard_hint(y_exp, "moe_ecd")

    # ---- combine: gather own slot, gate-weight, sum over k ----------------
    y_buf = jnp.concatenate([y_exp.reshape(e * cap, d), jnp.zeros((1, d), dt)], axis=0)
    y_tok = y_buf[slot] * (gates_f * keep).astype(dt)[:, None]  # [T*k, D]
    y = y_tok.reshape(t, top_k, d).sum(axis=1) if top_k > 1 else y_tok

    if "shared" in p:  # llama4's always-on shared expert
        y = y + mlp_apply(p["shared"], xf, kind)
    return y.reshape(b, s, d), aux
