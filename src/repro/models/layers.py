"""Shared layer primitives (pure functions over param pytrees).

No framework (flax/optax are not dependencies): a layer is
``init_*(key, ...) -> params`` plus ``apply(params, x, ...) -> y``.
Parameters are plain dicts of jnp arrays; the leading axis of block params
is the layer axis consumed by ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, dtype, fan_in=None):
    """Truncated-normal init scaled by 1/sqrt(fan_in) (fan_in = shape[-2])."""
    fan = fan_in if fan_in is not None else shape[-2]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm in fp32, cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies [head_dim // 2]."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, Hd], positions [..., S] -> rotated x (pairwise halves)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """x [..., D] -> [..., D].  swiglu | relu2 (Nemotron squared-ReLU) | gelu."""
    if kind == "swiglu":
        g = x @ p["w_gate"].astype(x.dtype)
        u = x @ p["w_up"].astype(x.dtype)
        h = jax.nn.silu(g) * u
    elif kind == "relu2":
        h = x @ p["w_up"].astype(x.dtype)
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = x @ p["w_up"].astype(x.dtype)
        h = jax.nn.gelu(h)
    else:  # pragma: no cover
        raise ValueError(f"unknown mlp kind {kind!r}")
    return h @ p["w_down"].astype(x.dtype)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------
def causal_mask(q_len: int, kv_len: int, q_offset) -> jnp.ndarray:
    """[q_len, kv_len] bool — True where query may attend (kv_pos <= q_pos)."""
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    return kpos <= qpos


def with_sharding(x: jnp.ndarray, spec) -> jnp.ndarray:
    """Apply a sharding constraint if a PartitionSpec is given (no-op outside
    jit / without a mesh context)."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
