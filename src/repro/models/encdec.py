"""Encoder-decoder LM (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings [B, S_src, D] (what the w2v-BERT
speech encoder would emit); this module implements the transformer backbone
— bidirectional encoder over frames, causal decoder with cross-attention —
plus the serving path (decoder KV cache + precomputed cross K/V).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_decode,
    attention_full,
    cross_attention,
    cross_attention_cached,
    init_attn,
    init_cross_attn,
    precompute_cross_kv,
)
from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, embed_init, init_mlp, mlp_apply, rmsnorm
from repro.models.transformer import _last_logits, chunked_ce
from repro.sharding.ctx import shard_hint

__all__ = [
    "init_encdec",
    "encdec_encode",
    "encdec_forward",
    "encdec_loss",
    "encdec_init_cache",
    "encdec_prefill",
    "encdec_decode_step",
]


def _adt(cfg):
    return jnp.dtype(cfg.dtype)


def _init_enc_layer(key, cfg: ModelConfig, pdt):
    k1, k2 = jax.random.split(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": init_attn(k1, d, cfg.n_heads, cfg.n_kv_heads, hd, pdt),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": init_mlp(k2, d, cfg.d_ff, cfg.mlp, pdt),
    }


def _init_dec_layer(key, cfg: ModelConfig, pdt):
    k1, k2, k3 = jax.random.split(key, 3)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": init_attn(k1, d, cfg.n_heads, cfg.n_kv_heads, hd, pdt),
        "lnx": jnp.ones((d,), jnp.float32),
        "xattn": init_cross_attn(k2, d, cfg.n_heads, cfg.n_kv_heads, hd, pdt),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": init_mlp(k3, d, cfg.d_ff, cfg.mlp, pdt),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    ke, kd, kemb, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": embed_init(kemb, (cfg.vocab, cfg.d_model), pdt),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg, pdt))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg, pdt))(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab), pdt),
    }


# --------------------------------------------------------------------------
def encdec_encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames [B, S_src, D] (stub frontend output) -> encoder memory."""
    x = frames.astype(_adt(cfg))
    x = shard_hint(x, "act_bsd")
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + attention_full(lp["attn"], h, positions, cfg.rope_theta, causal=False)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h2, cfg.mlp), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(scan_body, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def encdec_forward(
    params: Params, cfg: ModelConfig, frames: jnp.ndarray, tokens: jnp.ndarray
) -> jnp.ndarray:
    """Teacher-forced decode over [B, S_tgt] given source frames; returns
    final decoder hidden states [B, S_tgt, D]."""
    memory = encdec_encode(params, cfg, frames)
    x = params["embed"][tokens].astype(_adt(cfg))
    x = shard_hint(x, "act_bsd")
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + attention_full(lp["attn"], h, positions, cfg.rope_theta)
        hx = rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + cross_attention(lp["xattn"], hx, memory)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h2, cfg.mlp), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(scan_body, x, params["dec_blocks"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jnp.ndarray, dict]:
    hidden = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    ce, cnt = chunked_ce(hidden, params["lm_head"], batch["labels"], cfg.logit_chunk,
                         onehot_pick=cfg.onehot_ce)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32), "tokens": cnt}


# --------------------------------------------------------------------------
def encdec_init_cache(cfg: ModelConfig, batch: int, s_max: int, s_src: int) -> Params:
    hd = cfg.resolved_head_dim
    kv_shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, hd)
    cross_shape = (cfg.n_layers, batch, s_src, cfg.n_kv_heads, hd)
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros(kv_shape, jnp.bfloat16),
        "v": jnp.zeros(kv_shape, jnp.bfloat16),
        "ck": jnp.zeros(cross_shape, jnp.bfloat16),
        "cv": jnp.zeros(cross_shape, jnp.bfloat16),
    }


def encdec_prefill(
    params: Params,
    cfg: ModelConfig,
    frames: jnp.ndarray,
    tokens: jnp.ndarray,
    s_max: int | None = None,
) -> tuple[jnp.ndarray, Params]:
    """Encode source + teacher-forced pass over the target prefix, emitting
    decoder self-attn caches, precomputed cross K/V, and last logits."""
    memory = encdec_encode(params, cfg, frames)
    adt = _adt(cfg)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(adt)
    positions = jnp.arange(s)[None, :]
    from repro.models.layers import apply_rope

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["w_k"].astype(adt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["w_v"].astype(adt))
        kv = {
            "k": apply_rope(k, positions, cfg.rope_theta).astype(jnp.bfloat16),
            "v": v.astype(jnp.bfloat16),
        }
        x = x + attention_full(lp["attn"], h, positions, cfg.rope_theta)
        hx = rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + cross_attention(lp["xattn"], hx, memory)
        ck, cv = precompute_cross_kv(lp["xattn"], memory)
        kv["ck"], kv["cv"] = ck.astype(jnp.bfloat16), cv.astype(jnp.bfloat16)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h2, cfg.mlp), kv

    x, kvs = jax.lax.scan(body, x, params["dec_blocks"])
    from repro.models.transformer import _pad_cache_seq

    kvs = _pad_cache_seq(kvs, s, s_max or s)
    cache = {"pos": jnp.full((b,), s, jnp.int32), **kvs}
    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _last_logits(params, hidden), cache


def encdec_decode_step(
    params: Params, cfg: ModelConfig, cache: Params, tokens: jnp.ndarray
) -> tuple[jnp.ndarray, Params]:
    """One decoder step against cached self/cross K/V."""
    adt = _adt(cfg)
    pos = cache["pos"]
    x = params["embed"][tokens][:, None].astype(adt)

    def body(x, inp):
        lp, k, v, ck, cv = inp
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        out, nk, nv = attention_decode(lp["attn"], h, k, v, pos, cfg.rope_theta)
        x = x + out
        hx = rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + cross_attention_cached(lp["xattn"], hx, ck, cv)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h2, cfg.mlp), {"k": nk, "v": nv}

    x, kvs = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    new_cache = {"pos": pos + 1, "ck": cache["ck"], "cv": cache["cv"], **kvs}
    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _last_logits(params, hidden), new_cache
