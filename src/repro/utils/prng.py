"""Deterministic PRNG helpers shared across the framework.

Everything that samples (load scenarios, LT degree tables, synthetic data,
simulated completion times) threads an explicit seed through numpy's
``Generator`` or ``jax.random`` keys so that every experiment in
EXPERIMENTS.md is exactly reproducible.
"""
from __future__ import annotations

import numpy as np


def rng(seed: int) -> np.random.Generator:
    """A process-independent numpy Generator (PCG64)."""
    return np.random.Generator(np.random.PCG64(seed))


def derive(seed: int, *tags: int | str) -> int:
    """Derive a child seed from (seed, tags) — stable across runs/platforms."""
    h = int(seed)
    for t in tags:
        if isinstance(t, str):
            t = sum((i + 1) * b for i, b in enumerate(t.encode()))
        h = (h * 6364136223846793005 + int(t) * 2 + 1) % (1 << 64)
    return int(h % (2**31 - 1))
