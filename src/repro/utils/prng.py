"""Deterministic PRNG helpers shared across the framework.

Everything that samples (load scenarios, LT degree tables, synthetic data,
simulated completion times) threads an explicit seed through numpy's
``Generator`` or ``jax.random`` keys so that every experiment in
EXPERIMENTS.md is exactly reproducible.
"""
from __future__ import annotations

import numpy as np


def rng(seed: int) -> np.random.Generator:
    """A process-independent numpy Generator (PCG64)."""
    return np.random.Generator(np.random.PCG64(seed))


# --------------------------------------------------------------------------
# Fast per-seed generators for Monte-Carlo loops
# --------------------------------------------------------------------------
# ``np.random.PCG64(seed)`` costs ~50 us/call (allocation + lock + seeding
# machinery), which dominates vectorized Monte-Carlo sweeps that need one
# deterministic generator per trial.  PCG64's seeding is two LCG steps over
# the four SeedSequence words (numpy pcg64.c: pcg_setseq_128_srandom_r), so
# we compute the post-seeding state directly and write it into ONE reusable
# bit generator — bit-identical streams at ~2x the throughput.  A self-check
# against the reference constructor runs once; any mismatch (e.g. a future
# numpy changing its seeding path) falls back to ``rng`` transparently.
_PCG_MULT = (2549297995355413924 << 64) | 4865540595714422341
_MASK128 = (1 << 128) - 1


def _pcg64_seeded_state(seed: int) -> tuple[int, int]:
    w = np.random.SeedSequence(seed).generate_state(4, np.uint64)
    initstate = (int(w[0]) << 64) | int(w[1])
    initseq = (int(w[2]) << 64) | int(w[3])
    inc = ((initseq << 1) | 1) & _MASK128
    state = ((inc + initstate) * _PCG_MULT + inc) & _MASK128
    return state, inc


# SeedSequence's entropy-mixing hash (O'Neill seed_seq, 32-bit arithmetic;
# stream-stability is part of numpy's compatibility policy), vectorized
# across seeds: one [T]-lane uint32 pipeline replaces T sequential
# ``SeedSequence(seed).generate_state(4)`` calls.  The evolving hash
# constants are call-order-dependent but seed-independent, so they stay
# scalars while the data lanes vectorize.
_INIT_A = np.uint32(0x43B0D7E5)
_MULT_A = np.uint32(0x931E8875)
_INIT_B = np.uint32(0x8B51F9DD)
_MULT_B = np.uint32(0x58F38DED)
_MIX_L = np.uint32(0xCA01F9DD)
_MIX_R = np.uint32(0x4973F715)


def _seedseq_words_batch(seeds: np.ndarray) -> np.ndarray:
    """[T] uint32-range seeds -> [T, 4] uint64 == SeedSequence(s).generate_state(4)."""
    with np.errstate(over="ignore"):
        hc = [_INIT_A]  # evolving hash constant (shared across lanes)

        def hashmix(v):
            v = v ^ hc[0]
            hc[0] = hc[0] * _MULT_A
            v = v * hc[0]
            return v ^ (v >> np.uint32(16))

        def mix(x, y):
            r = x * _MIX_L - y * _MIX_R
            return r ^ (r >> np.uint32(16))

        ent = np.asarray(seeds, dtype=np.uint32)
        zeros = np.zeros_like(ent)
        pool = [hashmix(ent)] + [hashmix(zeros) for _ in range(3)]
        for i_src in range(4):
            for i_dst in range(4):
                if i_src != i_dst:  # hashmix per (src, dst): hc advances each
                    pool[i_dst] = mix(pool[i_dst], hashmix(pool[i_src]))
        hc[0] = _INIT_B
        out = np.empty((ent.shape[0], 8), dtype=np.uint32)
        for i in range(8):
            v = pool[i % 4] ^ hc[0]
            hc[0] = hc[0] * _MULT_B
            v = v * hc[0]
            out[:, i] = v ^ (v >> np.uint32(16))
        return out.view(np.uint64)


class _ScratchRng:
    def __init__(self):
        self._bg = np.random.PCG64()
        self._tmpl = self._bg.state
        self._ok = bool(
            np.array_equal(
                self._seeded(987654321).standard_normal(4),
                rng(987654321).standard_normal(4),
            )
        )

    def _seeded(self, seed: int) -> np.random.Generator:
        state, inc = _pcg64_seeded_state(seed)
        return self._set(state, inc)

    def _set(self, state: int, inc: int) -> np.random.Generator:
        self._tmpl["state"] = {"state": state, "inc": inc}
        self._tmpl["has_uint32"] = 0
        self._tmpl["uinteger"] = 0
        self._bg.state = self._tmpl
        return np.random.Generator(self._bg)

    def __call__(self, seed: int) -> np.random.Generator:
        if not self._ok:  # pragma: no cover - numpy-version escape hatch
            return rng(seed)
        return self._seeded(seed)

    def from_words(self, w: np.ndarray) -> np.random.Generator:
        """Generator from precomputed SeedSequence words [4] uint64."""
        initstate = (int(w[0]) << 64) | int(w[1])
        initseq = (int(w[2]) << 64) | int(w[3])
        inc = ((initseq << 1) | 1) & _MASK128
        state = ((inc + initstate) * _PCG_MULT + inc) & _MASK128
        return self._set(state, inc)


_scratch = None
_batch_ok = None


def rng_scratch(seed: int) -> np.random.Generator:
    """Like ``rng`` but reuses one bit generator: streams are bit-identical,
    construction is ~2x cheaper.  The returned Generator is INVALIDATED by
    the next ``rng_scratch`` call — draw from it immediately, never store it
    (made for tight one-generator-per-trial Monte-Carlo loops)."""
    global _scratch
    if _scratch is None:
        _scratch = _ScratchRng()
    return _scratch(seed)


def rng_scratch_iter(seeds: np.ndarray):
    """Yield one bit-identical Generator per seed, batch-seeded.

    The SeedSequence hash for ALL seeds runs as one vectorized uint32
    pipeline, then each trial costs only a PCG64 state install.  Same
    invalidation contract as ``rng_scratch``: consume each generator before
    advancing the iterator.  Self-checks against ``rng`` once per process
    and falls back to the reference constructor on any mismatch (or for
    seeds outside uint32 range, whose entropy spans multiple words).
    """
    global _scratch, _batch_ok
    if _scratch is None:
        _scratch = _ScratchRng()
    seeds = np.asarray(seeds)
    if _batch_ok is None:
        probe = np.array([0, 1, 987654321, 2**32 - 1], dtype=np.uint64)
        want = np.stack(
            [np.random.SeedSequence(int(s)).generate_state(4, np.uint64) for s in probe]
        )
        _batch_ok = bool(np.array_equal(_seedseq_words_batch(probe), want))
    in_range = (
        np.issubdtype(seeds.dtype, np.integer)
        and seeds.size > 0
        and int(seeds.min()) >= 0
        and int(seeds.max()) < 2**32
    )
    if _scratch._ok and _batch_ok and in_range:
        words = _seedseq_words_batch(seeds)
        for t in range(seeds.shape[0]):
            yield _scratch.from_words(words[t])
    else:  # pragma: no cover - escape hatch for exotic seeds / numpy drift
        for s in seeds:
            yield rng(int(s))


def derive(seed: int, *tags: int | str) -> int:
    """Derive a child seed from (seed, tags) — stable across runs/platforms."""
    h = int(seed)
    for t in tags:
        if isinstance(t, str):
            t = sum((i + 1) * b for i, b in enumerate(t.encode()))
        h = (h * 6364136223846793005 + int(t) * 2 + 1) % (1 << 64)
    return int(h % (2**31 - 1))
