"""HLO analysis: trip-count-aware FLOP/byte/collective accounting + roofline.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
tests/test_hlo.py), so a scanned-layers program under-reports by ~L x M.
``analyze_hlo`` instead walks the optimized HLO structurally:

  * computations are parsed into instruction tables,
  * the call graph (while / fusion / call / conditional / to_apply) is
    expanded with multipliers — ``while`` trip counts come from the
    ``backend_config={"known_trip_count":{"n":...}}`` annotation,
  * FLOPs  = Σ mult·2·|out|·K over every ``dot`` (MXU ops dominate; the
    elementwise tail is ignored, stated in EXPERIMENTS.md),
  * HBM bytes = Σ mult·(out + operands) over materializing instructions at
    computation level (fusion internals live in registers/VMEM),
  * wire bytes = Σ mult·bytes·wire_mult over collective instructions.

Per-op wire multipliers (ring algorithms, n -> inf):

    all-reduce          2x   (reduce-scatter + all-gather)
    all-gather          1x   (each device receives the full output once)
    reduce-scatter      1x
    all-to-all          1x
    collective-permute  1x

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment-specified).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = [
    "HW_V5E",
    "CollectiveStats",
    "collective_bytes",
    "analyze_hlo",
    "HloCosts",
    "Roofline",
    "roofline",
]


@dataclass(frozen=True)
class Hardware:
    peak_flops: float     # per chip, bf16
    hbm_bw: float         # bytes/s per chip
    ici_bw: float         # bytes/s per link


HW_V5E = Hardware(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_WIRE_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

# "%name = TYPE op(" where TYPE may be a tuple of shapes; async variants
# appear as op-start (count) + op-done (skip).
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_,\[\]{}:#()\s]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        size = _DTYPE_BYTES.get(dtype)
        if size is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * size
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, float] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def count(self) -> int:
        return sum(self.count_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output bytes x wire multiplier of every collective instruction
    in (optimized) HLO text.  ``-done`` ops are skipped (their ``-start``
    twin carries the shape)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line and "(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_text) * _WIRE_MULT[op]
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


# --------------------------------------------------------------------------
# structural HLO walk (trip-count aware)
# --------------------------------------------------------------------------
# header args may nest parens/tuples: match loosely on "(name (...) -> ... {"
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s*([\w\-]+)\((.*?)\)(.*)$"
)
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|true_computation|"
                    r"false_computation)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
}


@dataclass
class HloCosts:
    flops: float = 0.0            # dot FLOPs, trip-count expanded
    hbm_bytes: float = 0.0        # materializing-instruction traffic
    stats: CollectiveStats = field(default_factory=CollectiveStats)

    @property
    def wire_bytes(self) -> float:
        return self.stats.wire_bytes


_COMMENT = re.compile(r"/\*.*?\*/")


def _parse_computations(text: str) -> dict[str, list[tuple]]:
    comps: dict[str, list[tuple]] = {}
    cur: list[tuple] | None = None
    for raw in text.splitlines():
        # long tuple shapes carry /*index=N*/ comments whose '=' breaks the
        # instruction regex — strip comments before matching
        line = _COMMENT.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_HEAD.match(line.strip())
            if m:
                comps[m.group(2)] = cur = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, shape_text, op, operands, attrs = m.groups()
            # operand tokens may carry inline types ("f32[8]{0} %x") on newer
            # XLA text dumps or be bare ("%x") on older ones — take the names
            ops = re.findall(r"%([\w.\-]+)", operands)
            cur.append((name, shape_text.strip(), op, ops, attrs))
    return comps


def analyze_hlo(text: str) -> HloCosts:
    """Trip-count-expanded FLOPs / HBM bytes / collective bytes of one
    optimized per-device HLO module."""
    comps = _parse_computations(text)
    # find the entry computation (re-scan text for 'ENTRY')
    entry = None
    for line in text.splitlines():
        m = _COMP_HEAD.match(line.strip())
        if m and m.group(1):
            entry = m.group(2)
            break
    if entry is None:  # pragma: no cover
        raise ValueError("no ENTRY computation found")

    costs = HloCosts()
    fusion_called: set[str] = set()
    for instrs in comps.values():
        for name, shape_text, op, ops, attrs in instrs:
            if op == "fusion":
                m = _CALLS.search(attrs)
                if m:
                    fusion_called.add(m.group(1))

    def shape_table(comp: str) -> dict[str, str]:
        return {name: st for name, st, *_ in comps.get(comp, [])}

    def walk(comp: str, mult: float, in_fusion: bool, seen: tuple = ()):
        if comp not in comps or comp in seen:
            return
        table = shape_table(comp)
        for name, shape_text, op, ops, attrs in comps[comp]:
            # ---- recurse into called computations -----------------------
            trip = 1.0
            if op == "while":
                m = _TRIP.search(attrs)
                trip = float(m.group(1)) if m else 1.0
            called = _CALLS.findall(attrs)
            mb = _BRANCHES.search(attrs)
            if mb:
                called += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
            child_fusion = in_fusion or op == "fusion"
            for c in called:
                walk(c, mult * trip, child_fusion, seen + (comp,))
            # ---- dot FLOPs ----------------------------------------------
            if op == "dot":
                out_elems = 1
                sm = _SHAPE_RE.search(shape_text)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for d in dims:
                        out_elems *= d
                k = 1
                cm = _LHS_CDIMS.search(attrs)
                if cm and ops:
                    lhs_shape = table.get(ops[0], "")
                    lm = _SHAPE_RE.search(lhs_shape)
                    if lm:
                        ldims = [int(d) for d in lm.group(2).split(",") if d]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
                costs.flops += mult * 2.0 * out_elems * k
            # ---- collectives ---------------------------------------------
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _WIRE_MULT and not op.endswith("-done"):
                b = _shape_bytes(shape_text)
                if op.endswith("-start"):
                    b /= 2.0  # start tuples carry (input, output) buffers
                wb = b * _WIRE_MULT[base_op] * mult
                costs.stats.bytes_by_op[base_op] = (
                    costs.stats.bytes_by_op.get(base_op, 0.0) + wb
                )
                costs.stats.count_by_op[base_op] = (
                    costs.stats.count_by_op.get(base_op, 0) + int(mult)
                )
            # ---- HBM traffic ---------------------------------------------
            if not in_fusion and op not in _SKIP_BYTES:
                b = _shape_bytes(shape_text)
                for o in ops:
                    b += _shape_bytes(table.get(o, ""))
                costs.hbm_bytes += mult * b

    # walk entry; fusion-called computations are traversed from their call
    # sites with in_fusion=True, so only visit non-fusion roots here
    walk(entry, 1.0, False)
    return costs


# --------------------------------------------------------------------------
@dataclass
class Roofline:
    """Three-term roofline for one compiled (per-device) program."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    wire_bytes: float
    model_flops: float = 0.0   # analytic 6·N·D / 2·N·D useful FLOPs (per device)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU: useful FLOPs / (peak x bound-time)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops / (HW_V5E.peak_flops * self.bound_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def roofline(
    flops: float,
    hbm_bytes: float,
    wire_bytes: float,
    model_flops: float = 0.0,
    hw: Hardware = HW_V5E,
) -> Roofline:
    """All inputs are PER-DEVICE quantities of one step."""
    return Roofline(
        compute_s=flops / hw.peak_flops,
        memory_s=hbm_bytes / hw.hbm_bw,
        collective_s=wire_bytes / hw.ici_bw,
        flops=flops,
        hbm_bytes=hbm_bytes,
        wire_bytes=wire_bytes,
        model_flops=model_flops,
    )
