"""Deterministic synthetic LM data pipeline.

Design goals of a production input pipeline, scaled to this repo:

  * **checkpointable** — a batch is a pure function of (seed, step); resuming
    from step k replays the exact stream, so checkpoint/restart never skips
    or repeats data;
  * **sharded** — per-host slicing by (host_id, n_hosts) mirrors how a real
    multi-host pod feeds per-host shards of the global batch;
  * **learnable** — tokens follow an order-2 affine Markov chain with noise,
    so example runs show a real loss curve (not memorized noise);
  * **family-aware** — vlm batches carry stub patch embeddings, encdec
    batches carry stub frame embeddings (the assigned modality frontends
    are stubs per the task).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.config import ModelConfig
from repro.utils.prng import derive, rng as _rng

__all__ = ["SyntheticLM", "make_pipeline"]


@dataclass(frozen=True)
class SyntheticLM:
    cfg: ModelConfig
    seq: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    @property
    def local_batch(self) -> int:
        if self.global_batch % self.n_hosts != 0:
            raise ValueError("global batch must divide across hosts")
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Local shard of the global batch for ``step`` (deterministic)."""
        b, s, v = self.local_batch, self.seq, self.cfg.vocab
        g = _rng(derive(self.seed, "data", step, self.host_id))
        # order-2 affine Markov chain: x_t = (a*x_{t-1} + b*x_{t-2} + c + eps) % V
        a, bb, c = 31, 17, 7
        toks = np.zeros((b, s + 1), dtype=np.int64)
        toks[:, 0] = g.integers(0, v, size=b)
        toks[:, 1] = g.integers(0, v, size=b)
        noise = (g.random((b, s + 1)) < 0.05) * g.integers(0, v, size=(b, s + 1))
        for t in range(2, s + 1):
            toks[:, t] = (a * toks[:, t - 1] + bb * toks[:, t - 2] + c + noise[:, t]) % v
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.family == "vlm":
            out["img_embed"] = (
                g.standard_normal((b, self.cfg.img_tokens, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
        if self.cfg.family == "encdec":
            out["frames"] = (
                g.standard_normal((b, s, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
        return out

    def batches(self, start_step: int, n: int):
        for i in range(n):
            yield self.batch(start_step + i)


def make_pipeline(
    cfg: ModelConfig, seq: int, global_batch: int, seed: int = 0, **kw
) -> SyntheticLM:
    return SyntheticLM(cfg=cfg, seq=seq, global_batch=global_batch, seed=seed, **kw)
