"""repro — BPCC coded-computing reproduction (see ROADMAP.md, DESIGN.md).

Importing the package pins ``jax_threefry_partitionable`` on so that every
``jax.random`` draw is *sharding-invariant*: a parameter initialized under a
2x2 mesh is bit-identical to the single-device init (required by the elastic
resharding path and asserted in tests/test_multidevice.py).  This is the
default in newer JAX; we pin it explicitly for the 0.4.x floor.
"""
import jax as _jax

_jax.config.update("jax_threefry_partitionable", True)
