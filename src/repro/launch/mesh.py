"""Production mesh construction (function, not constant — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes", "model_axis_size"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips/pod; 2 pods multi-pod.

    Axes: 'pod' (slow inter-pod DCN/ICI), 'data' (DP + FSDP), 'model' (TP/EP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
