"""Serving launcher: batched decode with the BPCC coded head.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
        --requests 16 --coded --straggler-prob 0.2
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--coded", action="store_true",
                    help="BPCC coded LM head (straggler-tolerant logits)")
    ap.add_argument("--parity", type=int, default=2)
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-step probability each TP shard's result is lost")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.coded:
        cfg = cfg.scaled(coded=True, coded_parity=args.parity)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    mask_fn = None
    if args.coded and args.straggler_prob > 0:
        def mask_fn():
            m = np.ones(16)
            drop = rng.random(16) < args.straggler_prob
            # never drop more than the parity budget (a real deployment
            # would fall back to waiting for the slowest shard)
            idx = np.flatnonzero(drop)[: args.parity]
            m[idx] = 0.0
            return m

    eng = ServeEngine(model, params, n_slots=args.slots, s_max=args.s_max,
                      mask_fn=mask_fn)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:,.1f} tok/s) coded={args.coded} "
          f"straggler_prob={args.straggler_prob}")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
