"""Serving launcher: batched decode with the BPCC coded head.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
        --requests 16 --coded --straggler-prob 0.2

Continuous batching (``serve.engine.ServeEngine``): a fixed decode batch of
``--slots`` sequences, finished slots immediately refilled from the queue.
With ``--coded`` the LM-head matvec runs through the block-coded path — up
to ``--parity`` tensor-parallel shards may straggle or die per step and the
logits stay exact (DESIGN.md §2/§5).  With ``--adaptive-parity`` the number
of shards dropped per step is chosen from the recent straggler posterior
(``core.adaptive.ParityController``, DESIGN.md §8) instead of always
dropping the ``--parity`` slowest.

``--dry-run`` prints the fully-resolved serving configuration (model
config, coded-head geometry, engine settings) and exits without building
the model or executing a single step — the config-validation idiom.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Batched LM serving with the BPCC coded head",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", default="glm4-9b",
                    help="model architecture id (see repro.configs.ARCHS)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config sized for the CPU container")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of synthetic requests to serve")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots (batch size)")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="tokens per synthetic prompt")
    ap.add_argument("--max-new", type=int, default=32,
                    help="max new tokens generated per request")
    ap.add_argument("--s-max", type=int, default=128,
                    help="KV-cache capacity (max sequence length) per slot")
    ap.add_argument("--coded", action="store_true",
                    help="BPCC coded LM head (straggler-tolerant logits)")
    ap.add_argument("--parity", type=int, default=2,
                    help="parity shards of the coded head (erasure budget)")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-step probability each TP shard's result is lost")
    ap.add_argument("--adaptive-parity", action="store_true",
                    help="pick the per-step parity level from the online "
                         "straggler posterior (DESIGN.md §8) instead of "
                         "always dropping the full parity budget")
    ap.add_argument("--trace", choices=["none", "poisson", "bursty"],
                    default="none",
                    help="open-loop arrival trace (DESIGN.md §10): requests "
                         "arrive over wall-clock time with per-request "
                         "deadlines and admission control, instead of a "
                         "pre-loaded queue")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="trace mode: mean arrival rate, requests/second")
    ap.add_argument("--slo-factor", type=float, default=4.0,
                    help="trace mode: per-token deadline budget as a "
                         "multiple of the nominal step time")
    ap.add_argument("--t-token-est", type=float, default=0.05,
                    help="trace mode: nominal per-token wall-clock seconds "
                         "used to size deadlines (EW-corrected online)")
    ap.add_argument("--deadline-parity", action="store_true",
                    help="trace mode + --adaptive-parity: escalate the "
                         "parity level from SLO slack (DESIGN.md §10's "
                         "DeadlineAwareParity) rather than straggler "
                         "history alone")
    ap.add_argument("--tenants", type=int, default=1,
                    help="trace mode: SLO classes (DESIGN.md §13) — 1 is "
                         "the single default class; N>1 splits traffic "
                         "into N weighted-fair-queued tenants with "
                         "geometrically decaying weights and tightening "
                         "deadline factors")
    ap.add_argument("--tenant-parity", action="store_true",
                    help="with --deadline-parity and --tenants > 1: "
                         "per-class slack -> parity escalation "
                         "(TenantDeadlineParity) instead of the global "
                         "min-slack rule")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="trace mode: prompt tokens the engine may prefill "
                         "per step (prefill/decode disaggregation); "
                         "default refills every free slot")
    ap.add_argument("--macro-steps", type=int, default=1,
                    help="fused macro-step decode K_max (DESIGN.md §14): "
                         "decode up to K steps per jitted launch with one "
                         "host sync per block at batch-full steady state; "
                         "1 keeps the scalar per-token loop")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed (params, prompts, straggler draws)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved config and exit without executing")
    args = ap.parse_args()
    if args.adaptive_parity and not (args.coded and args.straggler_prob > 0):
        ap.error("--adaptive-parity requires --coded and --straggler-prob > 0 "
                 "(there is no straggler posterior to adapt to otherwise)")
    if args.deadline_parity and not (args.adaptive_parity and args.trace != "none"):
        ap.error("--deadline-parity requires --adaptive-parity and --trace "
                 "(SLO slack only exists under a deadline-bearing trace)")
    if args.tenants < 1:
        ap.error("--tenants must be >= 1")
    if args.tenant_parity and not (args.deadline_parity and args.tenants > 1):
        ap.error("--tenant-parity requires --deadline-parity and --tenants > 1")
    if args.macro_steps < 1:
        ap.error("--macro-steps must be >= 1")

    from repro.configs import get_config
    from repro.models.config import coded_blocks

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.coded:
        cfg = cfg.scaled(coded=True, coded_parity=args.parity)
    n_shards = coded_blocks(cfg)  # TP width of the coded LM head (jax-free)

    if args.dry_run:
        n_params, _ = cfg.param_count()
        print("[serve] --dry-run resolved config:")
        print(f"  arch={cfg.name} family={cfg.family} smoke={args.smoke} "
              f"params~{n_params:,.0f}")
        print(f"  d_model={cfg.d_model} n_layers={cfg.n_layers} "
              f"vocab={cfg.vocab}")
        print(f"  engine: slots={args.slots} s_max={args.s_max} "
              f"requests={args.requests} prompt_len={args.prompt_len} "
              f"max_new={args.max_new} macro_steps={args.macro_steps}")
        print(f"  coded={cfg.coded} parity={cfg.coded_parity if cfg.coded else 0} "
              f"shards={n_shards} straggler_prob={args.straggler_prob} "
              f"adaptive_parity={args.adaptive_parity}")
        if args.trace != "none":
            print(f"  traffic: trace={args.trace} rate={args.rate}/s "
                  f"slo_factor={args.slo_factor} t_token_est={args.t_token_est}s "
                  f"deadline_parity={args.deadline_parity} "
                  f"tenants={args.tenants} tenant_parity={args.tenant_parity} "
                  f"prefill_budget={args.prefill_budget}")
        return

    import jax

    from repro.core.adaptive import ParityController
    from repro.models.registry import build_model
    from repro.serve import Request, ServeEngine

    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    mask_fn = None
    latency_fn = None
    controller = None
    if args.coded and args.straggler_prob > 0:
        if args.adaptive_parity:
            # synthetic per-shard latencies with randomly-straggling shards,
            # observed through the HealthMonitor's EW estimator: the mask is
            # committed from backward-looking ESTIMATES (what a real
            # deployment knows pre-step, DESIGN.md §10), while the posterior
            # decides how many laggards to drop each step
            from repro.runtime.health import HealthMonitor

            monitor = HealthMonitor(n_workers=n_shards)

            def latency_fn():
                lat = 1e-3 * (1.0 + 0.1 * rng.random(n_shards))
                slow = rng.random(n_shards) < args.straggler_prob
                lat[slow] *= 50.0
                monitor.observe_step_latencies(lat)
                return monitor.shard_latencies()

            controller = ParityController(n_shards)
        else:
            def mask_fn():
                m = np.ones(n_shards)
                drop = rng.random(n_shards) < args.straggler_prob
                # never drop more than the parity budget (a real deployment
                # would fall back to waiting for the slowest shard)
                idx = np.flatnonzero(drop)[: args.parity]
                m[idx] = 0.0
                return m

    if args.trace != "none":
        # ---- trace-driven mode: open-loop arrivals + deadlines ----------
        from repro.core.adaptive import DeadlineAwareParity, TenantDeadlineParity
        from repro.serve import (
            SLOClass,
            TraceScheduler,
            bursty_trace,
            poisson_trace,
        )

        classes = None
        if args.tenants > 1:
            # premium tenants: higher WFQ weight, tighter per-token SLO,
            # slacker escalation (they start hedging earlier)
            classes = tuple(
                SLOClass(name=f"t{c}", weight=2.0 ** (args.tenants - 1 - c),
                         slo_factor=args.slo_factor * (1.0 + 0.5 * c),
                         share=1.0, escalate_steps=8.0 * (1.0 + c))
                for c in range(args.tenants)
            )
        mk = poisson_trace if args.trace == "poisson" else bursty_trace
        trace = mk(args.rate, args.requests, seed=args.seed,
                   mean_tokens=args.max_new, max_tokens=args.max_new,
                   t_token=args.t_token_est, slo_factor=args.slo_factor,
                   classes=classes)
        payloads = [
            Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new_tokens=int(trace.n_tokens[i]))
            for i in range(trace.n_requests)
        ]
        sched = TraceScheduler(trace, args.slots, t_step_init=args.t_token_est,
                               payloads=payloads)
        policy = None
        if args.deadline_parity and controller is not None:
            policy = (TenantDeadlineParity(controller, classes=trace.classes)
                      if args.tenant_parity
                      else DeadlineAwareParity(controller))
        t0 = time.monotonic()
        clock = lambda: time.monotonic() - t0  # noqa: E731
        eng = ServeEngine(model, params, n_slots=args.slots, s_max=args.s_max,
                          mask_fn=mask_fn, latency_fn=latency_fn,
                          parity_controller=controller, parity_policy=policy,
                          scheduler=sched, clock=clock,
                          prefill_budget=args.prefill_budget,
                          macro_steps=args.macro_steps)
        while not sched.finished:
            if eng.macro_step() == 0:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                time.sleep(max(0.0, nxt - clock()))
        res = sched.results()
        dt = clock()
        n_tok = int(res["n_tokens"][np.isfinite(res["t_complete"])].sum())
        syncs_per_tok = eng.sync_count / max(eng.tokens_emitted, 1)
        print(f"[serve] trace={args.trace} {trace.n_requests} requests, "
              f"{n_tok} tokens in {dt:.2f}s ({n_tok / max(dt, 1e-9):,.1f} tok/s)")
        print(f"  SLO attainment {res['slo_met'].mean():.1%}  "
              f"rejected {int(res['rejected'].sum())}  "
              f"est_step {sched.est_step_time * 1e3:.1f} ms  "
              f"deadline_parity={policy is not None}")
        print(f"  macro_steps={args.macro_steps}  fused_blocks={eng.macro_blocks}  "
              f"host_syncs/token={syncs_per_tok:.3f}")
        if args.tenants > 1:
            for c, cls in enumerate(trace.classes):
                sel = res["tenant"] == c
                att = res["slo_met"][sel].mean() if sel.any() else 1.0
                print(f"  class {cls.name}: weight={cls.weight:g} "
                      f"n={int(sel.sum())} attainment {att:.1%}")
        return

    eng = ServeEngine(model, params, n_slots=args.slots, s_max=args.s_max,
                      mask_fn=mask_fn, latency_fn=latency_fn,
                      parity_controller=controller,
                      macro_steps=args.macro_steps)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    syncs_per_tok = eng.sync_count / max(eng.tokens_emitted, 1)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:,.1f} tok/s) coded={args.coded} "
          f"straggler_prob={args.straggler_prob} "
          f"adaptive_parity={controller is not None} "
          f"macro_steps={args.macro_steps} "
          f"host_syncs/token={syncs_per_tok:.3f}")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
