"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \\
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20

Runs the full production stack on whatever devices exist (the CPU container
runs reduced/smoke configs on a 1x1 mesh; a TPU pod runs the real configs on
the production mesh): data pipeline -> pjit'd train step (microbatching,
remat, optional coded gradient aggregation) -> AdamW (int8 moments
optional) -> atomic checkpoints with restart, health-monitor hooks.

``--dry-run`` prints the fully-resolved training configuration (model,
mesh, optimizer, microbatching/gradient-coding plan) and exits before any
compilation or training step — the config-validation idiom.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data import make_pipeline
from repro.models.registry import build_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.runtime import latest_step, restore_checkpoint, save_checkpoint
from repro.runtime.checkpoint import gc_checkpoints
from repro.runtime.health import HealthMonitor
from repro.sharding.ctx import sharding_hints
from repro.sharding.policy import make_policy
from repro.train.loop import TrainConfig, init_train_state, make_train_step


def make_local_mesh():
    n = len(jax.devices())
    model = 1
    while model * 2 <= n and n % (model * 2) == 0 and model < 16:
        model *= 2
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="End-to-end LM training on the production stack",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", default="glm4-9b",
                    help="model architecture id (see repro.configs.ARCHS)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config sized for the CPU container")
    ap.add_argument("--steps", type=int, default=100,
                    help="training steps to run")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch size (sequences per step)")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length in tokens")
    ap.add_argument("--lr", type=float, default=3e-3,
                    help="peak learning rate (warmup-cosine schedule)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"],
                    help="AdamW moment storage dtype (int8 halves optimizer HBM)")
    ap.add_argument("--gradient-coding", default=None, choices=[None, "frc", "cyclic"],
                    help="coded gradient aggregation scheme across microbatches")
    ap.add_argument("--gc-stragglers", type=int, default=1,
                    help="straggler budget the gradient code must tolerate")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-step probability a coded grad message is dropped")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (None disables checkpointing)")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="save an (async, atomic) checkpoint every N steps")
    ap.add_argument("--log-every", type=int, default=10,
                    help="print loss/throughput every N steps")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed (init, data pipeline, straggler draws)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved config and exit without executing")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.dry_run:
        n_params, n_act = cfg.param_count()
        print("[train] --dry-run resolved config:")
        print(f"  arch={cfg.name} family={cfg.family} smoke={args.smoke} "
              f"params~{n_params:,.0f} (active~{n_act:,.0f})")
        print(f"  devices={len(jax.devices())} steps={args.steps} "
              f"batch={args.batch} seq={args.seq} lr={args.lr}")
        print(f"  microbatches={args.microbatches} moment_dtype={args.moment_dtype} "
              f"gradient_coding={args.gradient_coding} "
              f"gc_stragglers={args.gc_stragglers} "
              f"straggler_prob={args.straggler_prob}")
        print(f"  ckpt_dir={args.ckpt_dir} ckpt_every={args.ckpt_every}")
        return
    model = build_model(cfg)
    mesh = make_local_mesh()
    policy = make_policy(mesh, cfg)
    print(f"[train] arch={cfg.name} (smoke={args.smoke}) mesh={dict(mesh.shape)} "
          f"params~{model and sum(np.prod(s.shape) for s in jax.tree.leaves(model.param_shapes())):,}")

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps),
        moment_dtype=args.moment_dtype,
    )
    tc = TrainConfig(
        microbatches=args.microbatches,
        gradient_coding=args.gradient_coding,
        gc_stragglers=args.gc_stragglers,
    )
    step_fn = make_train_step(model, opt_cfg, tc)

    state_sds = jax.eval_shape(lambda k: init_train_state(model, k, opt_cfg),
                               jax.random.key(args.seed))
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            policy.state_specs(state_sds))
    jit_step = jax.jit(step_fn, in_shardings=(state_sh, None, None),
                       out_shardings=(state_sh, None), donate_argnums=(0,))

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, state = restore_checkpoint(args.ckpt_dir, state_sds,
                                          shardings=state_sh)
        print(f"[train] resumed from step {start}")
    else:
        with mesh:
            state = jax.jit(
                lambda k: init_train_state(model, k, opt_cfg), out_shardings=state_sh
            )(jax.random.key(args.seed))

    pipe = make_pipeline(cfg, seq=args.seq, global_batch=args.batch, seed=args.seed)
    health = HealthMonitor(n_workers=max(args.microbatches, 1))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    tokens_done = 0
    with mesh, sharding_hints(policy.hints()):
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, pipe.batch(step))
            mask = None
            if args.gradient_coding:
                m = (rng.random(args.microbatches) >= args.straggler_prob)
                if m.sum() < args.microbatches - args.gc_stragglers:
                    idx = rng.choice(args.microbatches,
                                     args.microbatches - args.gc_stragglers,
                                     replace=False)
                    m = np.zeros(args.microbatches, bool)
                    m[idx] = True
                mask = jnp.asarray(m, jnp.float32)
            ts = time.time()
            state, metrics = jit_step(state, batch, mask)
            health.record(0, rows=args.batch * args.seq, seconds=max(time.time() - ts, 1e-9))
            tokens_done += args.batch * args.seq
            if (step + 1) % args.log_every == 0 or step == start:
                print(f"[train] step {step+1:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"tok/s={tokens_done / (time.time() - t0):,.0f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, state, blocking=False)
                gc_checkpoints(args.ckpt_dir, keep=3)
    if args.ckpt_dir:
        from repro.runtime.checkpoint import wait_for_saves

        save_checkpoint(args.ckpt_dir, args.steps, state)
        wait_for_saves()
    print(f"[train] done in {time.time() - t0:.1f}s; "
          f"final loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
