"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \\
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20

Runs the full production stack on whatever devices exist (the CPU container
runs reduced/smoke configs on a 1x1 mesh; a TPU pod runs the real configs on
the production mesh): data pipeline -> pjit'd train step (microbatching,
remat, optional coded gradient aggregation) -> AdamW (int8 moments
optional) -> atomic checkpoints with restart, health-monitor hooks.

Coded mode (DESIGN.md §12) adds the full straggler-robust path:

  * per-step masks from a two-state Markov straggler stream
    (``cluster.straggler.MarkovStragglerPolicy`` — the serve bench's
    injection, per training step): with replication s the master waits for
    the first m−s coded messages, so the mask drops the s realized-slowest
    workers;
  * ``--adaptive-s``: the replication level is re-chosen online per step by
    ``core.adaptive.ReplicationController`` from the observed per-worker
    latencies (cost-model argmin; jit-compiled steps are cached per level);
  * ``--compress int8``: error-feedback int8 quantization of the coded
    messages (``optim.compression``), residuals carried in state["err"];
  * ``--kill-at N``: device-death drill — the last DP slice dies at step N,
    its workers' messages stop arriving (unrecoverable masks are *skipped*,
    params untouched), and after ``--detect-steps`` consecutive skips the
    elastic protocol runs: ``shrink_mesh`` -> ``restore_checkpoint`` with
    the survivor mesh's shardings -> training resumes.

``--dry-run`` prints the fully-resolved training configuration (model,
mesh, optimizer, microbatching/gradient-coding plan) and exits before any
compilation or training step — the config-validation idiom.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.cluster.straggler import MarkovStragglerPolicy
from repro.configs import get_config
from repro.core.adaptive import ReplicationController
from repro.data import make_pipeline
from repro.models.registry import build_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.runtime import (
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_saves,
)
from repro.runtime.elastic import shrink_mesh
from repro.runtime.health import HealthMonitor
from repro.sharding.ctx import sharding_hints
from repro.sharding.policy import make_policy
from repro.train.loop import TrainConfig, init_train_state, make_train_step


def make_local_mesh(model: int | None = None):
    n = len(jax.devices())
    if model is None:
        model = 1
        while model * 2 <= n and n % (model * 2) == 0 and model < 16:
            model *= 2
    elif n % model != 0:
        raise ValueError(f"--mesh-model {model} does not divide {n} devices")
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def _allowed_levels(kind: str, m: int, s_max: int) -> list[int]:
    """Replication levels the adaptive controller may pick from."""
    if kind == "frc":
        return [s for s in range(0, min(s_max, m - 1) + 1) if m % (s + 1) == 0]
    return list(range(0, min(s_max, m - 1) + 1))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="End-to-end LM training on the production stack",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", default="glm4-9b",
                    help="model architecture id (see repro.configs.ARCHS)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config sized for the CPU container")
    ap.add_argument("--steps", type=int, default=100,
                    help="training steps to run")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch size (sequences per step)")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length in tokens")
    ap.add_argument("--lr", type=float, default=3e-3,
                    help="peak learning rate (warmup-cosine schedule)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches per step "
                         "(= coded workers in gradient-coding mode)")
    ap.add_argument("--mesh-model", type=int, default=None,
                    help="TP width of the local mesh (default: widest that "
                         "fits; set small to leave DP slices for the drill)")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"],
                    help="AdamW moment storage dtype (int8 halves optimizer HBM)")
    ap.add_argument("--gradient-coding", default=None, choices=[None, "frc", "cyclic"],
                    help="coded gradient aggregation scheme across microbatches")
    ap.add_argument("--gc-stragglers", type=int, default=1,
                    help="straggler budget s (maximum level when --adaptive-s)")
    ap.add_argument("--adaptive-s", action="store_true",
                    help="re-choose the replication level online from the "
                         "ReplicationController's latency posterior")
    ap.add_argument("--compress", default=None, choices=[None, "int8"],
                    help="error-feedback compression of the coded messages")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="stationary straggler fraction of the Markov "
                         "injection (paper §5.3.1 uses 0.2)")
    ap.add_argument("--straggler-slowdown", type=float, default=3.0,
                    help="compute-time multiplier while slow (paper: 3x)")
    ap.add_argument("--straggler-persistence", type=float, default=25.0,
                    help="mean steps a slow regime lasts")
    ap.add_argument("--straggler-onset", type=float, default=None,
                    help="per-step onset probability (overrides --straggler-prob)")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="device-death drill: the last DP slice dies at this "
                         "step; elastic shrink/restore resumes training")
    ap.add_argument("--detect-steps", type=int, default=2,
                    help="consecutive unrecoverable steps before the death "
                         "drill declares the slice dead and re-meshes")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (None disables checkpointing)")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="save an (async, atomic) checkpoint every N steps")
    ap.add_argument("--log-every", type=int, default=10,
                    help="print loss/throughput every N steps")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed (init, data pipeline, straggler draws)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved config and exit without executing")
    args = ap.parse_args(argv)

    if args.kill_at is not None and not args.ckpt_dir:
        ap.error("--kill-at needs --ckpt-dir (restore-with-resharding)")
    if args.kill_at is not None and not args.gradient_coding:
        ap.error("--kill-at needs --gradient-coding (masks detect the death)")

    cfg = get_config(args.arch, smoke=args.smoke)
    m = args.microbatches
    if args.dry_run:
        n_params, n_act = cfg.param_count()
        print("[train] --dry-run resolved config:")
        print(f"  arch={cfg.name} family={cfg.family} smoke={args.smoke} "
              f"params~{n_params:,.0f} (active~{n_act:,.0f})")
        print(f"  devices={len(jax.devices())} steps={args.steps} "
              f"batch={args.batch} seq={args.seq} lr={args.lr}")
        print(f"  microbatches={m} moment_dtype={args.moment_dtype} "
              f"gradient_coding={args.gradient_coding} "
              f"gc_stragglers={args.gc_stragglers} adaptive_s={args.adaptive_s} "
              f"compress={args.compress}")
        print(f"  straggler: prob={args.straggler_prob} "
              f"slowdown={args.straggler_slowdown} "
              f"persistence={args.straggler_persistence} "
              f"onset={args.straggler_onset}")
        print(f"  ckpt_dir={args.ckpt_dir} ckpt_every={args.ckpt_every} "
              f"kill_at={args.kill_at}")
        return
    model = build_model(cfg)
    mesh = make_local_mesh(args.mesh_model)
    print(f"[train] arch={cfg.name} (smoke={args.smoke}) mesh={dict(mesh.shape)} "
          f"params~{model and sum(np.prod(s.shape) for s in jax.tree.leaves(model.param_shapes())):,}")

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps),
        moment_dtype=args.moment_dtype,
    )

    def train_cfg(s: int) -> TrainConfig:
        return TrainConfig(
            microbatches=m,
            gradient_coding=args.gradient_coding,
            gc_stragglers=s,
            compression=args.compress,
        )

    tc0 = train_cfg(args.gc_stragglers)
    state_sds = jax.eval_shape(
        lambda k: init_train_state(model, k, opt_cfg, tc0), jax.random.key(args.seed)
    )

    # --- mesh-dependent pieces, rebuilt by the elastic protocol ------------
    jit_cache: dict[int, object] = {}
    policy = state_sh = None

    def install_mesh(new_mesh):
        nonlocal mesh, policy, state_sh
        mesh = new_mesh
        policy = make_policy(mesh, cfg)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                policy.state_specs(state_sds))
        jit_cache.clear()

    def jit_step(s: int):
        if s not in jit_cache:
            step_fn = make_train_step(model, opt_cfg, train_cfg(s))
            jit_cache[s] = jax.jit(
                step_fn, in_shardings=(state_sh, None, None),
                out_shardings=(state_sh, None), donate_argnums=(0,),
            )
        return jit_cache[s]

    install_mesh(mesh)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, state = restore_checkpoint(args.ckpt_dir, state_sds,
                                          shardings=state_sh)
        print(f"[train] resumed from step {start}")
    else:
        with mesh:
            state = jax.jit(
                lambda k: init_train_state(model, k, opt_cfg, tc0),
                out_shardings=state_sh,
            )(jax.random.key(args.seed))

    # --- straggler injection + online replication control ------------------
    stream = None
    if args.gradient_coding and (args.straggler_prob > 0 or args.straggler_onset):
        if args.straggler_onset is not None:
            pol = MarkovStragglerPolicy(
                onset=args.straggler_onset, slow_factor=args.straggler_slowdown,
                persistence=args.straggler_persistence)
        else:
            pol = MarkovStragglerPolicy.from_stationary(
                args.straggler_prob, slow_factor=args.straggler_slowdown,
                persistence=args.straggler_persistence)
        stream = pol.stream(m, seed=args.seed)
    controller = ReplicationController(m) if args.adaptive_s else None
    levels = _allowed_levels(args.gradient_coding or "cyclic", m,
                             args.gc_stragglers)
    s_cur = args.gc_stragglers if args.gradient_coding else 0

    pipe = make_pipeline(cfg, seq=args.seq, global_batch=args.batch, seed=args.seed)
    health = HealthMonitor(n_workers=max(m, 1))
    dead_ranks: set[int] = set()
    consec_bad = 0
    skipped = 0
    t0 = time.time()
    tokens_done = 0
    step = start
    while step < args.steps:
        with mesh, sharding_hints(policy.hints()):
            batch = jax.tree.map(jnp.asarray, pipe.batch(step))
            mask = None
            if args.gradient_coding:
                if controller is not None:
                    s_cur = controller.replication(levels)
                mult = stream.step() if stream is not None else np.ones(m)
                if dead_ranks:
                    dp = mesh.shape.get("data", 1)
                    dead_w = [w for w in range(m) if (w % dp) in dead_ranks]
                    mult = mult.copy()
                    mult[dead_w] = np.inf
                # master waits for the first m - s messages: drop the s
                # realized-slowest (dead workers never arrive at all)
                alive = np.isfinite(mult)
                keep = np.zeros(m, bool)
                order = np.argsort(mult)
                keep[order[: max(m - s_cur, 1)]] = True
                keep &= alive
                mask = jnp.asarray(keep, jnp.float32)
                if controller is not None:
                    controller.observe(np.where(alive, mult, np.inf))
            ts = time.time()
            state, metrics = jit_step(s_cur)(state, batch, mask) \
                if args.gradient_coding else jit_step(0)(state, batch)
            health.record(0, rows=args.batch * args.seq,
                          seconds=max(time.time() - ts, 1e-9))
            ok = float(metrics.get("ok", 1.0))
            if ok < 0.5:
                skipped += 1
                consec_bad += 1
            else:
                consec_bad = 0
                tokens_done += args.batch * args.seq
            if (step + 1) % args.log_every == 0 or step == start:
                print(f"[train] step {step+1:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} s={s_cur} "
                      f"ok={ok:.0f} tok/s={tokens_done / (time.time() - t0):,.0f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0 and ok >= 0.5:
                save_checkpoint(args.ckpt_dir, step + 1, state, blocking=False)
                gc_checkpoints(args.ckpt_dir, keep=3)

        # --- device-death drill + elastic recovery ------------------------
        if args.kill_at is not None and step + 1 == args.kill_at:
            dp = mesh.shape.get("data", 1)
            if dp > 1:
                dead_ranks.add(dp - 1)
                print(f"[train] drill: DP slice {dp - 1} died at step {step + 1}")
            else:
                print("[train] drill skipped: mesh has a single DP slice")
        if dead_ranks and consec_bad >= args.detect_steps:
            print(f"[train] {consec_bad} unrecoverable steps -> elastic recovery")
            wait_for_saves()
            dp = mesh.shape.get("data", 1)
            dead_dev = {d.id for i, row in enumerate(mesh.devices)
                        for d in np.asarray(row).flat if i in dead_ranks} \
                if mesh.devices.ndim > 1 else set()
            new_mesh = shrink_mesh(mesh, dead_dev)
            install_mesh(new_mesh)
            ck_step, state = restore_checkpoint(args.ckpt_dir, state_sds,
                                                shardings=state_sh)
            print(f"[train] re-meshed {dp}->{new_mesh.shape.get('data', 1)} DP "
                  f"slices; resumed from checkpoint step {ck_step}")
            dead_ranks.clear()
            consec_bad = 0
            step = ck_step
            continue
        step += 1
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
        wait_for_saves()
    print(f"[train] done in {time.time() - t0:.1f}s; skipped={skipped}; "
          f"final loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
