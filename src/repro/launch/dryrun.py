"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \\
        --out reports/dryrun.json

This is the proof that the distribution config is coherent without real
hardware: ``.lower().compile()`` must succeed for the 16x16 (256-chip) pod
mesh AND the 2x16x16 (512-chip) multi-pod mesh for every cell, and the
compiled artifact yields the memory/cost/collective numbers the roofline
analysis (EXPERIMENTS.md §Roofline) reads.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count on first init, so this MUST precede every import.
# Inherited force flags are stripped first: XLA keeps the LAST duplicate
# flag, and callers (e.g. a pytest parent whose conftest forces 16 devices
# for the shard_map serving tests) would otherwise silently override the
# 512 this launcher requires.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + " ".join(
        t
        for t in os.environ.get("XLA_FLAGS", "").split()
        if not t.startswith("--xla_force_host_platform_device_count")
    )
).strip()

import argparse    # noqa: E402
import json        # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable, get_config  # noqa: E402
from repro.configs.shapes import Workload  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.sharding.ctx import sharding_hints  # noqa: E402
from repro.sharding.policy import make_policy  # noqa: E402
from repro.train.loop import TrainConfig, make_train_step  # noqa: E402
from repro.utils.hlo import analyze_hlo, roofline  # noqa: E402

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------
# per-arch training plan (what a launcher config file would pin)
# --------------------------------------------------------------------------
def train_plan(cfg: ModelConfig) -> dict:
    n, _ = cfg.param_count()
    if n >= 50e9:
        # int8 moments + per-sequence microbatches + sequence-sharded
        # activations: required to fit 16 GB/chip (DESIGN.md §5)
        return {"moment_dtype": "int8", "microbatches": 16, "seq_shard_act": True}
    if n >= 8e9:
        return {"moment_dtype": "float32", "microbatches": 4, "seq_shard_act": False}
    return {"moment_dtype": "float32", "microbatches": 1, "seq_shard_act": False}


# --------------------------------------------------------------------------
# analytic useful-FLOPs (global): 6·N·D train / 2·N·D forward (+ attn reads)
# --------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, wl: Workload) -> float:
    _, n_act = cfg.param_count()
    t = wl.batch * wl.seq
    hd = cfg.resolved_head_dim
    if wl.kind == "train":
        attn = 12 * cfg.n_layers * wl.batch * wl.seq**2 * cfg.n_heads * hd
        return 6.0 * n_act * t + (attn if cfg.n_heads else 0)
    if wl.kind == "prefill":
        attn = 4 * cfg.n_layers * wl.batch * wl.seq**2 * cfg.n_heads * hd
        return 2.0 * n_act * t + (attn if cfg.n_heads else 0)
    # decode: one token per sequence + KV attention over the cache
    attn = 4 * cfg.n_layers * wl.batch * wl.seq * cfg.n_heads * hd
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        attn = 4 * n_apps * wl.batch * wl.seq * cfg.n_heads * hd
    if cfg.family == "ssm":
        attn = 0
    return 2.0 * n_act * wl.batch + attn


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------
_PLAN_KEYS = {"microbatches", "moment_dtype", "seq_shard_act", "shard_grad_accum"}


def build_cell(cfg: ModelConfig, wl: Workload, mesh, *, coded: bool = False,
               overrides: dict | None = None):
    """Returns (jitted_fn, example_args (SDS), meta).

    ``overrides``: perf-iteration knobs — ModelConfig fields (onehot_ce,
    pad_heads, moe_dispatch_groups, aligned_decode, param_dtype, ...) or
    train-plan fields (microbatches, moment_dtype, seq_shard_act).
    """
    if coded:
        cfg = cfg.scaled(coded=True)
    plan_over = {}
    if overrides:
        cfg_over = {k: v for k, v in overrides.items() if k not in _PLAN_KEYS}
        plan_over = {k: v for k, v in overrides.items() if k in _PLAN_KEYS}
        if cfg_over:
            cfg = cfg.scaled(**cfg_over)
    model = build_model(cfg)
    plan = {**train_plan(cfg), **plan_over}
    small_batch = wl.batch < mesh.shape.get("data", 1)
    # decode cells whose KV cache is sequence-sharded (KV heads don't divide
    # TP) also contraction-shard the attn projections — see ShardingPolicy
    seq_sharded_cache = (
        wl.kind == "decode"
        and cfg.n_kv_heads > 0
        and cfg.n_kv_heads % mesh.shape.get("model", 1) != 0
    )
    policy = make_policy(
        mesh, cfg, fsdp=True, shard_cache_seq=small_batch,
        qkv_contraction=seq_sharded_cache,
    )
    ns = lambda spec: NamedSharding(mesh, spec)
    param_sds = model.param_shapes()
    param_sh = jax.tree.map(ns, policy.param_specs(param_sds))

    hints = policy.hints()
    if wl.kind == "train" and plan["seq_shard_act"]:
        hints = dict(hints)
        hints["act_bsd"] = ns(P(policy.dp_axes, "model", None))

    if wl.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=plan["moment_dtype"])
        tc = TrainConfig(microbatches=plan["microbatches"])
        grad_sh = (
            jax.tree.map(ns, policy.param_specs(param_sds))
            if plan.get("shard_grad_accum", True) and tc.microbatches > 1
            else None
        )
        step = make_train_step(model, opt_cfg, tc, grad_shardings=grad_sh)
        from repro.optim import init_opt_state

        state_sds = {
            "params": param_sds,
            "opt": jax.eval_shape(lambda: init_opt_state(param_sds, opt_cfg)),
        }
        state_sh = jax.tree.map(ns, policy.state_specs(state_sds))
        batch_sds = model.input_specs("train", wl.batch, wl.seq)
        batch_sh = jax.tree.map(ns, policy.batch_specs(batch_sds))
        fn = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        return fn, (state_sds, batch_sds), hints

    if wl.kind == "prefill":
        batch_sds = model.input_specs("prefill", wl.batch, wl.seq)
        batch_sh = jax.tree.map(ns, policy.batch_specs(batch_sds))
        fn = jax.jit(
            lambda p, b: model.prefill(p, b),
            in_shardings=(param_sh, batch_sh),
        )
        return fn, (param_sds, batch_sds), hints

    if wl.kind == "decode":
        cache_sds = model.cache_shapes(wl.batch, wl.seq)
        cache_sh = jax.tree.map(ns, policy.cache_specs(cache_sds))
        tok_sds = SDS((wl.batch,), jnp.int32)
        tok_sh = ns(P(policy.dp_axes if not small_batch else None))
        fn = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t),
            in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        return fn, (param_sds, cache_sds, tok_sds), hints

    raise ValueError(wl.kind)


# --------------------------------------------------------------------------
def run_cell(arch: str, shape: str, multi_pod: bool, coded: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    wl = SHAPES[shape]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        fn, args_sds, hints = build_cell(cfg, wl, mesh, coded=coded,
                                         overrides=overrides)
        with mesh, sharding_hints(hints):
            lowered = fn.lower(*args_sds)
            compiled = lowered.compile()
        t_compile = time.time() - t0

        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        # cost_analysis counts while bodies ONCE; analyze_hlo expands trip
        # counts structurally (utils/hlo.py) — it is the roofline source.
        costs = analyze_hlo(hlo)
        mflops = model_flops(cfg, wl) / chips
        rl = roofline(costs.flops, costs.hbm_bytes, costs.wire_bytes,
                      model_flops=mflops)
        coll = costs.stats

        mem_d = {}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    mem_d[k] = int(v)
        result = {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "coded": coded, "status": "ok", "chips": chips,
            "compile_s": round(t_compile, 1),
            "memory": mem_d,
            "cost_xla_body_once": {
                k: cost[k] for k in ("flops", "bytes accessed") if k in cost
            },
            "collectives": {
                "bytes_by_op": coll.bytes_by_op,
                "count_by_op": coll.count_by_op,
                "wire_bytes": coll.wire_bytes,
            },
            "roofline": rl.as_dict(),
        }
        print(f"[dryrun] {arch} x {shape} x {'2pod' if multi_pod else '1pod'}"
              f"{' coded' if coded else ''}: OK "
              f"compile={t_compile:.0f}s dominant={rl.dominant} "
              f"bound={rl.bound_s*1e3:.2f}ms mfu_bound={rl.mfu_bound:.2%}")
        print(f"  memory_analysis: {mem_d}")
        print(f"  hlo_analysis: flops={costs.flops:.3e} bytes={costs.hbm_bytes:.3e} "
              f"wire={coll.wire_bytes:.3e}")
        return result
    except Exception as e:  # noqa: BLE001 — report failures as data
        print(f"[dryrun] {arch} x {shape} x {'2pod' if multi_pod else '1pod'}: "
              f"FAIL {type(e).__name__}: {e}")
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "coded": coded, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--coded", action="store_true",
                    help="enable the BPCC coded serving head")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON report here")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already ok/skipped in --out")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="perf knob: key=value (int/bool/str inferred); "
                         "repeatable — e.g. --set onehot_ce=1 --set microbatches=4")
    ap.add_argument("--dry-run", action="store_true",
                    help="list the resolved (arch x shape x mesh) cells with "
                         "applicability and the per-arch train plan, without "
                         "lowering or compiling anything")
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, _, v = kv.partition("=")
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v
    if overrides:
        print(f"[dryrun] overrides: {overrides}")

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    if args.dry_run:
        # resolved-plan listing, no device work: the config-validation idiom
        n_cells = 0
        for arch in archs:
            cfg = get_config(arch)
            plan = train_plan(cfg)
            n, _ = cfg.param_count()
            print(f"[dryrun] --dry-run {arch}: family={cfg.family} "
                  f"params~{n:,.0f} train_plan={plan}")
            for shape in shapes:
                ok, why = applicable(cfg, shape)
                for mp in pods:
                    tag = "2pod" if mp else "1pod"
                    status = "ok" if ok else f"skip ({why})"
                    print(f"    x {shape} x {tag}"
                          f"{' coded' if args.coded else ''}: {status}")
                    n_cells += ok
        print(f"[dryrun] --dry-run: {n_cells} compilable cells resolved; "
              f"nothing compiled")
        return

    done: set = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                if r["status"] in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["multi_pod"],
                              r.get("coded", False)))
        print(f"[dryrun] resume: {len(done)} cells already complete")

    key = lambda r: (r["arch"], r["shape"], r["multi_pod"], r.get("coded", False))

    def persist(results):
        if not args.out:
            return
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        merged = {key(r): r for r in existing}
        for r in results:
            merged[key(r)] = r
        tmp = args.out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        os.replace(tmp, args.out)

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                if (arch, shape, mp, args.coded) in done:
                    continue
                results.append(run_cell(arch, shape, mp, coded=args.coded,
                                        overrides=overrides or None))
                persist(results)  # incremental: survive kills/restarts
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed")
    if args.out:
        print(f"[dryrun] wrote {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
