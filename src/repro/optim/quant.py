"""Shape-preserving block-wise int8 quantization (optimizer moments, grads).

8-bit Adam moments cut optimizer-state HBM from 8 to ~2.03 bytes/param —
what lets the 340B/400B train cells fit 256 x 16 GB chips (DESIGN.md §5).

Two properties matter at pod scale:

  * **shape preservation** — ``q`` has exactly the parameter's shape (int8)
    and ``scale`` has the parameter's leading dims, so both inherit the
    parameter PartitionSpecs and FSDP-shard with the weights.  (A flattened
    layout would lose the dims GSPMD needs.)  Blocks run along the LAST
    axis, 256 values per fp32 scale (1.6% overhead).
  * **companding** — plain max-scaled linear int8 zeroes every element ≪
    block-max; for Adam's second moment that collapses 1/sqrt(v) and the
    optimizer *diverges* (reproduced in tests).  ``pow=4`` stores
    |x|^(1/4), covering ~8.5 decades with bounded relative error; int8
    Adam then tracks fp32 Adam step-for-step.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    q: jnp.ndarray        # int8, same shape as the source tensor
    scale: jnp.ndarray    # fp32 [..., ceil(last/BLOCK)] — per-block max
    shape: tuple          # original shape — STATIC aux data, not a child
    pow: int = 1          # companding exponent (static)

    def tree_flatten(self):
        return (self.q, self.scale), (tuple(self.shape), self.pow)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(q=children[0], scale=children[1], shape=aux[0], pow=aux[1])

    @property
    def nbytes_effective(self) -> int:
        return self.q.size + 4 * self.scale.size


def quantize(x: jnp.ndarray, pow: int = 1) -> QTensor:
    """Per-row scales (one fp32 scale per trailing vector, keepdims max).

    NOT fixed-size blocks: a block reshape whose boundary straddles shard
    boundaries (e.g. BLOCK=256 over a 5120/16=320-wide FSDP shard) makes
    GSPMD all-gather the whole tensor at every (de)quantize — measured as
    2 x 8 GB all-gathers per step on the 400B cell.  A keepdims row-max is
    shard-local; the 4th-root companding supplies the dynamic range that
    small blocks would otherwise provide (validated vs fp32 Adam in
    tests/test_optim.py).
    """
    shape = x.shape
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), 1e-30)
    y = xf / scale
    if pow != 1:
        y = jnp.sign(y) * jnp.abs(y) ** (1.0 / pow)
    q = jnp.clip(jnp.round(127.0 * y), -127, 127).astype(jnp.int8)
    if x.ndim == 0:
        q = q[0]
    return QTensor(q=q.reshape(shape), scale=scale, shape=shape, pow=pow)


def dequantize(t: QTensor) -> jnp.ndarray:
    qf = t.q.astype(jnp.float32)
    if qf.ndim == 0:
        qf = qf[None]
    y = qf / 127.0
    if t.pow != 1:
        y = jnp.sign(y) * jnp.abs(y) ** t.pow
    return (y * t.scale).reshape(t.shape)
