"""Gradient-message compression with error feedback.

Distributed-optimization trick for the DP all-reduce: quantize the gradient
message to int8 before the collective and carry the quantization residual
into the next step (error feedback keeps the *accumulated* update unbiased,
so convergence matches fp32 aggregation asymptotically — verified on the
quickstart model in tests).

In the SPMD train step this wraps the explicit gradient aggregation used by
the coded-DP path; with plain pjit DP the all-reduce is XLA-inserted and
compression applies at the pod boundary (cross-pod reduce in the launcher).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.quant import QTensor, dequantize, quantize

__all__ = ["init_error_state", "compress_with_feedback", "decompress"]


def init_error_state(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def compress_with_feedback(grads: Any, err: Any) -> tuple[Any, Any]:
    """Returns (quantized message tree, new error state)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = quantize(corrected)
        new_e = corrected - dequantize(q)
        return q, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    msgs = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return msgs, new_err


def decompress(msgs: Any) -> Any:
    return jax.tree.map(
        lambda q: dequantize(q), msgs, is_leaf=lambda x: isinstance(x, QTensor)
    )
