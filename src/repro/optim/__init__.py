from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_opt_state  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_with_feedback,
    decompress,
    init_error_state,
)
from repro.optim.quant import QTensor, dequantize, quantize  # noqa: F401
from repro.optim.schedule import constant, warmup_cosine  # noqa: F401
