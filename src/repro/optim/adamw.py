"""AdamW with optional int8 moments (no optax dependency).

The optimizer state mirrors the parameter pytree, so it inherits the
parameter PartitionSpecs — FSDP over `data` shards the moments with the
weights (ZeRO).  ``moment_dtype='int8'`` swaps both moments for block-
quantized ``QTensor``s (2.06 bytes/param instead of 8), requantized every
step; the quantization error is unbiased at the block level and measured
against fp32 Adam in tests/test_optim.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.quant import QTensor, dequantize, quantize

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8

    def lr_at(self, step) -> jnp.ndarray:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)


def _zeros_moment(p: jnp.ndarray, kind: str):
    if kind == "int8":
        return quantize(jnp.zeros(p.shape, jnp.float32), pow=4)
    return jnp.zeros(p.shape, jnp.dtype(kind))


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: _zeros_moment(p, cfg.moment_dtype), params,
                          is_leaf=lambda x: isinstance(x, QTensor)),
        "v": jax.tree.map(lambda p: _zeros_moment(p, cfg.moment_dtype), params,
                          is_leaf=lambda x: isinstance(x, QTensor)),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = dequantize(m) if is_q(m) else m.astype(jnp.float32)
        vf = dequantize(v) if is_q(v) else v.astype(jnp.float32)
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if is_q(m):
            return new_p, quantize(mf, pow=4), quantize(vf, pow=4)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
