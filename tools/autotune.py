"""Empirical kernel autotuner -> the committed dispatch table.

    PYTHONPATH=src python tools/autotune.py [--quick] [--reps N]

Measures the kernel candidate grid on THIS host (the CPU container), fits
the analytical cost model's hardware constants to the measurements
(``repro.kernels.cost.fit_hardware``), reconciles measured vs predicted
(cells where the model errs by more than ``MODEL_ERROR_FLAG`` = 2x are
flagged in the table; winners above ``MODEL_ERROR_BOUND`` = 4x fail the
bench gate in tools/bench_compare.py), and persists the per-shape dispatch
table ``reports/bench/autotune.json`` that ``kernel_mode="auto"`` consults
(``repro.kernels.dispatch``, DESIGN.md §11).

Measurement discipline (hard-won — see benchmarks/decode_bench.py):

  * candidates are timed as ARG-PASSING jitted callables (a zero-arg jit
    closing over inputs lets XLA constant-fold the whole computation);
  * candidates at one shape are timed INTERLEAVED (round-robin reps,
    median per candidate) — sequential timing drifts with the host's load
    and produced the spurious 0.98x "regression" the seed table carried;
  * ``coded_linear`` candidates run INTEGRATED through
    ``CodedLinear.apply`` under one outer jit — how they execute in
    production (a separately-jitted kernel pays its own dispatch floor);
  * interpret-mode Pallas rows are measured for the record but marked
    ``excluded`` — interpreter overhead is not kernel performance, and
    they are never winners nor calibration samples.

CPU entries are measured; TPU entries are model-derived (``source:
"model"``) — the tile choosers size the Pallas kernels for the v5e VMEM
budget, restricted to the kernel-capable candidates, so a TPU run of the
same shapes starts from sized tiles instead of defaults.

Output goes to ``$BENCH_REPORT_DIR/autotune.json`` when the scratch
redirect is set (CI consistency job), else to the committed
``reports/bench/autotune.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

REPORT_DIR = os.environ.get(
    "BENCH_REPORT_DIR", os.path.join(REPO, "reports", "bench")
)

# the measured grid: (op, shape tuple, geometry).  The quick subset is the
# 3-cell grid the CI autotune-consistency job re-measures.
CODED_LINEAR_GEOM = {"n_data": 12, "n_parity": 4}  # the 16-block serving head
CELLS_FULL = [
    ("coded_linear", (4096, 1024, 8)),
    ("coded_linear", (1024, 256, 8)),
    ("coded_linear", (256, 512, 4)),
    ("coded_matvec", (2048, 1024, 8)),
    ("coded_matvec", (512, 512, 4)),
    ("gaussian_encode", (256, 1024, 2048)),
    ("gaussian_encode", (64, 256, 512)),
]
CELLS_QUICK = [
    ("coded_linear", (1024, 256, 8)),
    ("coded_linear", (256, 512, 4)),
    ("gaussian_encode", (64, 256, 512)),
]


def time_interleaved(fns: dict[str, tuple], reps: int = 25,
                     slow_reps: int = 3) -> dict[str, float]:
    """Round-robin timing: fns[name] = (callable, is_slow).  Every rep
    cycles through all LIVE candidates once, so slow drift hits them
    equally; per-candidate median in us.  ``is_slow`` candidates
    (interpret mode — orders of magnitude slower, and running one between
    live reps evicts their working set) are timed AFTER the interleaved
    group, sequentially, with ``slow_reps`` reps."""
    import jax

    for fn, _ in fns.values():
        jax.block_until_ready(fn())  # compile outside the timed region
    samples: dict[str, list[float]] = {k: [] for k in fns}

    def one(name, fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples[name].append(time.perf_counter() - t0)

    live = {k: f for k, (f, slow) in fns.items() if not slow}
    for _ in range(reps):
        for name, fn in live.items():
            one(name, fn)
    for name, (fn, slow) in fns.items():
        if slow:
            for _ in range(slow_reps):
                one(name, fn)
    import numpy as np

    return {k: float(np.median(v) * 1e6) for k, v in samples.items()}


def _coded_linear_candidates(out, inner, b):
    """Jitted arg-passing candidates through CodedLinear.apply."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.coded_ops import CodedLinear

    n_data, n_parity = CODED_LINEAR_GEOM["n_data"], CODED_LINEAR_GEOM["n_parity"]
    rng = np.random.default_rng(0)
    cl = CodedLinear(n_data=n_data, n_parity=n_parity, out_features=out)
    w = rng.standard_normal((out, inner)).astype(np.float32)
    wc = jnp.asarray(np.asarray(cl.encode(jnp.asarray(w))))
    x = jnp.asarray(rng.standard_normal((inner, b)).astype(np.float32))
    m = np.ones(n_data + n_parity, np.float32)
    m[[3, 11]] = 0.0
    m = jnp.asarray(m)

    def make(mode):
        f = jax.jit(lambda wc_, x_, m_: cl.apply(wc_, x_, m_, kernel_mode=mode))
        return lambda: f(wc, x, m)

    return {
        "default": (make(None), False, None),
        "svd": (make("svd"), False, None),
        "fused": (make("off"), False, "off"),
        "fused_interpret": (make("interpret"), True, "interpret"),
    }


def _matvec_candidates(r, m, b):
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import coded_matvec

    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((r, m)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, b)).astype(np.float32))

    def make(mode):
        f = jax.jit(lambda a_, x_: coded_matvec(a_, x_, mode=mode))
        return lambda: f(a, x)

    return {
        "ref": (make("off"), False, "off"),
        "pallas_interpret": (make("interpret"), True, "interpret"),
    }


def _encode_candidates(q, r, m):
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import gaussian_encode

    rng = np.random.default_rng(2)
    g = jnp.asarray((rng.standard_normal((q, r)) / np.sqrt(r)).astype(np.float32))
    a = jnp.asarray(rng.standard_normal((r, m)).astype(np.float32))

    def make(mode):
        f = jax.jit(lambda g_, a_: gaussian_encode(g_, a_, mode=mode))
        return lambda: f(g, a)

    return {
        "ref": (make("off"), False, "off"),
        "pallas_interpret": (make("interpret"), True, "interpret"),
    }


def _geom(op, shape):
    if op == "coded_linear":
        out, inner, b = shape
        return dict(out=out, inner=inner, batch=b, **CODED_LINEAR_GEOM)
    if op == "coded_matvec":
        r, m, b = shape
        return dict(r=r, m=m, b=b)
    if op == "gaussian_encode":
        q, r, m = shape
        return dict(q=q, r=r, m=m)
    raise ValueError(op)


# impl name in the measured candidate dict -> cost-model impl key
_COST_IMPL = {
    "default": "default", "svd": "svd", "fused": "fused",
    "fused_interpret": "fused", "ref": "ref", "pallas_interpret": "pallas",
}


def measure_cells(cells, reps: int) -> list[dict]:
    makers = {
        "coded_linear": _coded_linear_candidates,
        "coded_matvec": _matvec_candidates,
        "gaussian_encode": _encode_candidates,
    }
    measured = []
    for op, shape in cells:
        cands = makers[op](*shape)
        us = time_interleaved(
            {k: (fn, slow) for k, (fn, slow, _mode) in cands.items()},
            reps=reps,
        )
        rows = []
        for name, (_fn, slow, mode) in cands.items():
            rows.append({
                "impl": _COST_IMPL[name], "measured_as": name, "mode": mode,
                "us": us[name], "excluded": bool(slow),
            })
        measured.append({"op": op, "shape": shape, "candidates": rows})
        print(f"  {op} {'x'.join(map(str, shape))}: "
              + "  ".join(f"{r['measured_as']}={r['us']:.1f}us"
                          + ("(excluded)" if r["excluded"] else "")
                          for r in rows))
    return measured


def build_table(measured: list[dict], backend: str) -> dict:
    from repro.kernels import cost

    # ---- calibrate the hardware constants on non-excluded rows ----------
    samples = []
    for cell in measured:
        costs = cost.candidate_costs(cell["op"], "cpu", **_geom(cell["op"], cell["shape"]))
        for r in cell["candidates"]:
            if not r["excluded"] and r["impl"] in costs:
                samples.append((costs[r["impl"]], r["us"]))
    hw = cost.fit_hardware(samples, base=cost.preset(backend))

    # ---- reconcile + pick winners ---------------------------------------
    entries = []
    n_flagged = 0
    for cell in measured:
        op, shape = cell["op"], cell["shape"]
        geom = _geom(op, shape)
        costs = cost.candidate_costs(op, "cpu", **geom)
        for r in cell["candidates"]:
            kc = costs.get(r["impl"])
            if kc is None or r["excluded"]:
                r["predicted_us"] = None
                r["model_error"] = None
                continue
            r["predicted_us"] = kc.predicted_us(hw)
            r["model_error"] = cost.model_error(r["predicted_us"], r["us"])
            r["flagged"] = r["model_error"] > cost.MODEL_ERROR_FLAG
            n_flagged += r["flagged"]
        live = [r for r in cell["candidates"] if not r["excluded"]]
        win = min(live, key=lambda r: r["us"])
        shape_key = "x".join(map(str, shape))
        entries.append({
            "op": op, "shape": shape_key, "dtype": "float32",
            "backend": backend,
            "geometry": (CODED_LINEAR_GEOM if op == "coded_linear" else {}),
            "impl": win["impl"], "mode": win["mode"], "params": {},
            "us": win["us"], "predicted_us": win["predicted_us"],
            "model_error": win["model_error"], "flagged": win["flagged"],
            "source": "measured", "candidates": cell["candidates"],
        })
        if win["flagged"]:
            print(f"  FLAG {op} {shape_key}: winner {win['impl']} model_error "
                  f"{win['model_error']:.2f}x > {cost.MODEL_ERROR_FLAG}x")

    # ---- model-derived TPU rows: sized tiles for the kernel path ---------
    tpu_hw = cost.preset("tpu")
    for cell in measured:
        op, shape = cell["op"], cell["shape"]
        geom = _geom(op, shape)
        costs = cost.candidate_costs(op, "tpu", **geom)
        # TPU rows pin the kernel-capable impl (the compiled Pallas path)
        # with modeled tiles — a real-TPU rerun of this tool would replace
        # them with measurements
        kernel_impls = [k for k in costs if k in ("fused", "pallas")]
        impl = min(kernel_impls, key=lambda k: costs[k].predicted_us(tpu_hw))
        entries.append({
            "op": op, "shape": "x".join(map(str, shape)), "dtype": "float32",
            "backend": "tpu",
            "geometry": (CODED_LINEAR_GEOM if op == "coded_linear" else {}),
            "impl": impl, "mode": "compile",
            "params": cost.tile_params(op, **geom),
            "us": None, "predicted_us": costs[impl].predicted_us(tpu_hw),
            "model_error": None, "flagged": False, "source": "model",
            "candidates": [],
        })

    from repro.core import decoding

    nd, np_ = CODED_LINEAR_GEOM["n_data"], CODED_LINEAR_GEOM["n_parity"]
    doc = {
        "version": 1,
        "generated_by": "tools/autotune.py",
        "backend": backend,
        "reps_interleaved": True,
        "hardware": {backend: hw.as_dict(), "tpu": tpu_hw.as_dict()},
        "decoder_cache": {
            "n_data": nd, "n_parity": np_,
            "patterns": cost.decodable_patterns(nd, np_),
            "max_lut_patterns": decoding.MAX_LUT_PATTERNS,
            "recommended_max_patterns": cost.recommended_max_patterns(hw),
            "worthwhile": cost.decoder_cache_worthwhile(nd, np_, hw),
        },
        "flagged_cells": int(n_flagged),
        "entries": entries,
    }
    return doc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="3-cell subset (the CI consistency grid)")
    ap.add_argument("--reps", type=int, default=25,
                    help="interleaved timing rounds per cell")
    ap.add_argument("--out", default=None,
                    help="output path (default: REPORT_DIR/autotune.json)")
    args = ap.parse_args()

    import jax

    backend = jax.default_backend()
    cells = CELLS_QUICK if args.quick else CELLS_FULL
    print(f"# autotune: backend={backend} cells={len(cells)} "
          f"reps={args.reps} quick={args.quick}")
    measured = measure_cells(cells, reps=args.reps)
    doc = build_table(measured, backend)

    out = args.out or os.path.join(REPORT_DIR, "autotune.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    hw = doc["hardware"][backend]
    print(f"# fitted {backend}: gemm={hw['gemm_flops']:.3g} flop/s "
          f"bw={hw['mem_bw']:.3g} B/s dispatch={hw['dispatch_us']:.1f}us "
          f"node={hw['node_us']:.2f}us svd={hw['svd_us']:.3g}us")
    print(f"# wrote {out}: {len(doc['entries'])} entries, "
          f"{doc['flagged_cells']} flagged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
