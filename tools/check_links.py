#!/usr/bin/env python
"""Markdown link/anchor checker for intra-repo references (CI docs job).

    python tools/check_links.py README.md DESIGN.md docs CHANGES.md

Checks every markdown link ``[text](target)`` in the given files (and
``*.md`` under given directories), ignoring external schemes
(http/https/mailto).  A relative target must exist on disk, and a
``#fragment`` must match a GitHub-slugified heading of the target file
(or of the same file for bare ``#fragment`` links).  Exits non-zero and
lists every dead reference.  No third-party dependencies.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor algorithm: lowercase, drop everything but word
    characters/spaces/hyphens, spaces -> hyphens."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def heading_slugs(path: str) -> set[str]:
    slugs: dict[str, int] = {}
    out = set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            n = slugs.get(slug, 0)
            slugs[slug] = n + 1
            out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def md_files(targets: list[str]) -> list[str]:
    files = []
    for t in targets:
        if os.path.isdir(t):
            for root, _dirs, names in os.walk(t):
                files.extend(
                    os.path.join(root, n) for n in sorted(names) if n.endswith(".md")
                )
        elif os.path.exists(t):
            files.append(t)
        else:
            print(f"warning: {t} does not exist, skipping", file=sys.stderr)
    return files


def check_file(path: str) -> list[str]:
    errors = []
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(EXTERNAL) or target.startswith("<"):
                    continue
                ref, _, frag = target.partition("#")
                if ref:
                    dest = os.path.normpath(os.path.join(os.path.dirname(path), ref))
                    if not os.path.exists(dest):
                        errors.append(f"{path}:{lineno}: broken path {target!r}")
                        continue
                else:
                    dest = path
                if frag:
                    if not dest.endswith(".md") or os.path.isdir(dest):
                        continue  # anchors into non-markdown: not checked
                    if frag.lower() not in heading_slugs(dest):
                        errors.append(
                            f"{path}:{lineno}: dead anchor {target!r} "
                            f"(no heading slug {frag!r} in {dest})"
                        )
    return errors


def main(argv: list[str]) -> int:
    targets = argv or ["README.md", "DESIGN.md", "docs", "CHANGES.md"]
    files = md_files(targets)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    all_errors = []
    for path in files:
        all_errors.extend(check_file(path))
    for e in all_errors:
        print(e)
    print(
        f"checked {len(files)} file(s): "
        + ("OK" if not all_errors else f"{len(all_errors)} dead reference(s)")
    )
    return 1 if all_errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
