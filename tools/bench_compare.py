"""Guard the committed benchmark baselines against drift and clobbering.

    PYTHONPATH=src python tools/bench_compare.py [--skip-run] [--scratch DIR]

Runs the perf benchmark blocks in ``--quick`` mode into a SCRATCH directory
(``BENCH_REPORT_DIR`` — never the committed ``reports/bench/``; the PR-3
incident was a quick rerun overwriting the full-mode ``BENCH_decode.json``
in place), then diffs the fresh artifacts against the committed baselines:

  * schema: every baseline column must still be produced (a silently
    renamed/dropped field breaks downstream figure tooling);
  * invariants: the scale-free claims each baseline encodes must hold in
    the fresh run too, with tolerance thresholds — quick mode shrinks
    trial counts and shapes, so ABSOLUTE numbers are never compared:
      - decode:     the cached decode stays faster than the SVD seed path;
      - streaming:  residual decode beats terminal, decodes stay exact;
      - adaptive:   adaptive <= static per cell, engines bit-identical,
                    batch-vs-algorithm1 speedup above the quick floor;
      - kernels:    every (kernel, shape) has both interpret + off rows;
      - train:      coded tokens/sec above uncoded in every straggler cell,
                    coded p99 below uncoded at the violent (slow >= 10)
                    cells, the known-rates oracle bounds both arms, and
                    every real-jit fidelity row passed;
      - serve:      trial-batched simulator bit-identical to the scalar
                    loop in every cell, adaptive attainment >= fixed,
                    coded goodput above uncoded under injection, goodput
                    monotone in decode occupancy, and no SLO class
                    starved under WFQ admission;
      - engine:     fused macro-step decode bit-identical to the scalar
                    engine in every (K, slots) cell, K=64 at least K=1
                    tokens/sec at every batch-full cell, and >= 4x fewer
                    host syncs per token at K=64 (DESIGN.md §14);
      - executor:   wall-clock backends payload-bit-identical to the
                    model-time oracle in every cell, paced wall completion
                    inside a loose band around the scaled model schedule,
                    BPCC not above HCMM (with quick-jitter headroom), and
                    every unpaced throughput trial decoded OK;
  * upload: the fresh encode-kernel rows (``gaussian_encode``) are merged
    into the committed ``reports/bench/kernels.json`` so the new kernel's
    numbers ride along without hand-editing (other rows untouched);
  * autotune: the committed dispatch table (``reports/bench/autotune.json``,
    DESIGN.md §11) is checked statically — no interpret-mode winners (an
    interpret-built table would dispatch production traffic to the Pallas
    interpreter), measured winners within ``MODEL_ERROR_BOUND`` of the cost
    model — and, when a fresh quick re-measure exists in the scratch dir,
    for CONSISTENCY: each committed winner must be within ``AUTOTUNE_TOL``
    of the freshly measured best at the same cell (near-tie flips are fine;
    a committed winner that is now 2x off is a stale table).
    ``--autotune-only`` runs just that re-measure + check (the CI
    autotune-consistency job); ``--train-only`` runs just the quick train
    bench + its gate (the CI coded-training job); ``--serve-only`` runs
    just the quick serve bench + its gate (the CI serve-batch job);
    ``--engine-only`` runs just the quick engine bench + its check_engine
    gate (the CI engine-fused job); ``--executor-only`` runs just the quick
    executor bench + its check_executor gate (the CI executor-wallclock
    job — real OS processes, so that job retries once on jitter).

Exit code 0 = baselines healthy; 1 = a check failed (printed).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.kernels.cost import MODEL_ERROR_BOUND  # noqa: E402

BASELINE_DIR = os.path.join(REPO, "reports", "bench")
BLOCKS = "kernels,decode,streaming,adaptive,serve,engine,train,executor"
FILES = ["kernels", "BENCH_decode", "BENCH_streaming", "BENCH_adaptive",
         "BENCH_serve", "BENCH_engine", "BENCH_train", "BENCH_executor"]
TRAIN_P99_SLOW = 10.0  # p99 gate applies at cells this violent or worse
#                        (at the paper's 3x tier an onset step necessarily
#                        costs ~2x a slow step, and onsets are p99-frequent,
#                        so no causal policy can win the 3x tail; see
#                        benchmarks/train_bench.py)
ADAPTIVE_QUICK_SPEEDUP = 2.5   # matches benchmarks/adaptive_bench.py
DECODE_MIN_ADVANTAGE = 1.0     # cached decode at least matches the SVD path
STREAMING_MIN_ADVANTAGE = 1.0  # residual decode at least matches terminal
AUTOTUNE_TOL = 2.0  # committed winner vs fresh best: default/fused are
#                     genuine near-ties on CPU (flip run-to-run within
#                     +-10%); 2x catches a stale or wrong-host table
#                     without tripping on tie flips

_failures: list[str] = []


def fail(msg: str) -> None:
    _failures.append(msg)
    print(f"FAIL: {msg}")


def load(d: str, name: str):
    path = os.path.join(d, f"{name}.json")
    if not os.path.exists(path):
        fail(f"{name}: missing artifact {path}")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        fail(f"{name}: unparseable JSON ({e})")
        return None


def check_schema(name: str, baseline: list[dict], fresh: list[dict]) -> None:
    if not baseline or not fresh:
        fail(f"{name}: empty row list (baseline={len(baseline or [])}, "
             f"fresh={len(fresh or [])})")
        return
    base_keys = set().union(*(r.keys() for r in baseline))
    fresh_keys = set().union(*(r.keys() for r in fresh))
    missing = base_keys - fresh_keys
    if missing:
        fail(f"{name}: fresh run dropped baseline columns {sorted(missing)}")


def check_decode(fresh: list[dict]) -> None:
    for r in fresh:
        if r.get("mode") == "interpret":
            continue  # interpreter overhead, not kernel performance
        adv = r.get("svd_over_cached")
        if adv is not None and adv < DECODE_MIN_ADVANTAGE:
            fail(f"decode: cached path lost its advantage in {r.get('bench')} "
                 f"{r.get('shape')} (svd_over_cached={adv:.2f})")
        adv = r.get("svd_over_auto")
        if adv is not None and adv < DECODE_MIN_ADVANTAGE:
            fail(f"decode: auto dispatch lost to the SVD seed in "
                 f"{r.get('bench')} {r.get('shape')} (svd_over_auto={adv:.2f}, "
                 f"auto={r.get('auto_impl')}/{r.get('auto_mode')} from "
                 f"{r.get('auto_source')})")


def check_streaming(fresh: list[dict]) -> None:
    for r in fresh:
        if r.get("ok") is False:
            fail(f"streaming: decode failed in {r.get('bench')} r={r.get('r')}")
        adv = r.get("residual_speedup")
        if adv is not None and adv < STREAMING_MIN_ADVANTAGE:
            fail(f"streaming: residual decode slower than terminal "
                 f"({r.get('bench')} {r.get('code')} r={r.get('r')}: {adv:.2f}x)")


def check_adaptive(fresh: list[dict]) -> None:
    for r in fresh:
        if r.get("scheme") == "ENGINE_TOTALS":
            if r.get("engine_speedup", 0.0) < ADAPTIVE_QUICK_SPEEDUP:
                fail(f"adaptive: quick-grid engine speedup "
                     f"{r['engine_speedup']:.2f}x < {ADAPTIVE_QUICK_SPEEDUP}x")
            continue
        if not r.get("bit_identical", False):
            fail(f"adaptive: batch engine not bit-identical in "
                 f"({r.get('scheme')}, p={r.get('p')}, mag={r.get('drift_mag')}, "
                 f"churn={r.get('churn_rate')})")
        if r.get("mean_adaptive", 0.0) > r.get("mean_static", 0.0) * (1 + 1e-9):
            fail(f"adaptive: adaptive mean worse than static in "
                 f"({r.get('scheme')}, p={r.get('p')}, mag={r.get('drift_mag')}, "
                 f"churn={r.get('churn_rate')})")


def check_serve(fresh: list[dict]) -> None:
    """The serve bench's acceptance relations, re-checked on the fresh run
    (all scale-free — quick mode shrinks the trace, not the relations):

      * every cell's trial-batched run proved bit-identical to the scalar
        simulator (the ``bit_identical`` column, DESIGN.md §13);
      * traffic grid: adaptive SLO attainment >= fixed per cell, and coded
        goodput above uncoded in every straggler-injection cell;
      * occupancy sweep: goodput strictly monotone in decode slots per
        policy (rate scales with slots, so capacity must show up as
        goodput), and no SLO class starves under WFQ admission in the
        CODED arms (uncoded starving the tight class at violent injection
        is the measured pathology, not a fairness bug)."""
    for r in fresh:
        if not r.get("bit_identical", False):
            fail(f"serve: batched simulator not bit-identical to the scalar "
                 f"loop in ({r.get('bench')}, {r.get('trace')}, "
                 f"onset={r.get('onset')}, policy={r.get('policy')}, "
                 f"slots={r.get('n_slots')})")
    cells: dict[tuple, dict] = {}
    sweep: dict[str, list[dict]] = {}
    for r in fresh:
        if r.get("bench") == "serve_occupancy":
            sweep.setdefault(r["policy"], []).append(r)
            continue
        cells.setdefault((r["trace"], r["onset"], r["slow_factor"]), {})[
            r["policy"]
        ] = r
    for key, pols in cells.items():
        if not {"uncoded", "fixed", "adaptive"} <= set(pols):
            fail(f"serve: cell {key} missing a policy arm (have {sorted(pols)})")
            continue
        if pols["adaptive"]["attainment"] < pols["fixed"]["attainment"]:
            fail(f"serve: adaptive attainment below fixed in {key} "
                 f"({pols['adaptive']['attainment']:.3f} < "
                 f"{pols['fixed']['attainment']:.3f})")
        if key[1] > 0:
            for coded in ("fixed", "adaptive"):
                if pols[coded]["goodput"] <= pols["uncoded"]["goodput"]:
                    fail(f"serve: {coded} goodput not above uncoded in {key}")
    if not sweep:
        fail("serve: no serve_occupancy sweep rows in the fresh run")
    for policy, prows in sweep.items():
        prows.sort(key=lambda r: r["n_slots"])
        for lo, hi in zip(prows, prows[1:]):
            if hi["goodput"] <= lo["goodput"]:
                fail(f"serve: goodput not monotone in occupancy for {policy} "
                     f"({lo['n_slots']} slots -> {lo['goodput']:.3f}, "
                     f"{hi['n_slots']} slots -> {hi['goodput']:.3f})")
        if policy == "uncoded":
            continue  # uncoded starving the tight class IS the measured
            #           pathology (serve_bench.py) — only coded arms gate
        for r in prows:
            if r.get("min_class_served_frac", 0.0) <= 0.0:
                fail(f"serve: an SLO class starved under WFQ "
                     f"({policy}, {r['n_slots']} slots)")


def check_engine(fresh: list[dict]) -> None:
    """The engine bench's acceptance relations (ISSUE 9), re-checked on
    the fresh run — all scale-free (quick mode shrinks the slots grid,
    never the relations):

      * every (K, slots) cell's fused engine emitted the scalar engine's
        exact token streams (the ``bit_identical`` column — re-proved per
        cell against the K=1 run on identical prompts, DESIGN.md §14);
      * K=64 tokens/sec at least the scalar engine's in every batch-full
        slots group (the fused path must never cost throughput);
      * >= 4x fewer host syncs per token at K=64 vs K=1 per slots group
        (a deterministic counter relation: one transfer per fused block
        instead of one per token row)."""
    for r in fresh:
        if not r.get("bit_identical", False):
            fail(f"engine: fused decode not bit-identical to the scalar "
                 f"engine at (k={r.get('k')}, slots={r.get('n_slots')})")
    groups: dict[int, dict[int, dict]] = {}
    for r in fresh:
        groups.setdefault(r["n_slots"], {})[r["k"]] = r
    for n_slots, cells in groups.items():
        if not {1, 64} <= set(cells):
            fail(f"engine: {n_slots}-slot group missing the K=1/K=64 arms "
                 f"(have K={sorted(cells)})")
            continue
        k1, k64 = cells[1], cells[64]
        if k64["tok_per_s"] < k1["tok_per_s"]:
            fail(f"engine: K=64 below scalar tokens/sec at {n_slots} slots "
                 f"({k64['tok_per_s']:.0f} < {k1['tok_per_s']:.0f})")
        ratio = k1["syncs_per_token"] / max(k64["syncs_per_token"], 1e-12)
        if ratio < 4.0:
            fail(f"engine: host-sync reduction below 4x at {n_slots} slots "
                 f"({ratio:.1f}x)")


EXECUTOR_SCHEME_HEADROOM = 1.10  # quick mode: 2 paired seeds, wall jitter —
#                                  BPCC may not beat HCMM by the full-run
#                                  margin, but must never be 10% worse
EXECUTOR_WALL_BAND = (0.95, 1.5)  # paced completion vs scaled model
#                                   schedule: pacing guarantees >=, delivery
#                                   cost bounds <= (plus a 1 s constant)


def check_executor(fresh: list[dict]) -> None:
    """The executor bench's acceptance relations (DESIGN.md §15), re-checked
    on the fresh quick run:

      * every identity cell (code x tier) proved the wall-clock backend's
        payload bit-identical to the model-time oracle and decoded OK;
      * straggler cells: payload identity held per trial, paced wall
        completion sits in a loose sanity band around the scaled model
        schedule (the READY handshake makes pacing exact to milliseconds;
        the band only catches gross regressions), and BPCC mean wall
        completion is not above HCMM's beyond quick-jitter headroom;
      * throughput cells: every unpaced trial decoded OK and the
        requests-per-second figure is a positive finite number."""
    ident = [r for r in fresh if r.get("bench") == "executor_identity"]
    strag = {r["scheme"]: r for r in fresh
             if r.get("bench") == "executor_straggler"}
    thru = [r for r in fresh if r.get("bench") == "executor_throughput"]
    cells = {(r["code"], r["backend"]) for r in ident}
    want = {(c, t) for c in ("lt", "gaussian") for t in ("thread", "process")}
    if cells != want:
        fail(f"executor: identity grid incomplete (have {sorted(cells)})")
    for r in ident:
        if not (r.get("payload_identical") and r.get("ok")):
            fail(f"executor: {r['backend']} backend broke the determinism "
                 f"contract at code={r['code']} (payload_identical="
                 f"{r.get('payload_identical')}, ok={r.get('ok')})")
    if set(strag) != {"bpcc", "hcmm"}:
        fail(f"executor: straggler section missing a scheme arm "
             f"(have {sorted(strag)})")
    else:
        for scheme, r in strag.items():
            if not r.get("payload_identical"):
                fail(f"executor: straggler cell {scheme} lost payload "
                     f"identity on the process backend")
            wall, sched = r["mean_T_wall"], r["mean_T_model_scaled"]
            lo, hi = EXECUTOR_WALL_BAND
            if not (lo * sched <= wall <= hi * sched + 1.0):
                fail(f"executor: paced wall completion outside the sanity "
                     f"band for {scheme} (wall={wall:.3f}s, scaled model="
                     f"{sched:.3f}s)")
        if strag["bpcc"]["mean_T_wall"] > \
                strag["hcmm"]["mean_T_wall"] * EXECUTOR_SCHEME_HEADROOM:
            fail(f"executor: BPCC wall completion above HCMM beyond "
                 f"headroom ({strag['bpcc']['mean_T_wall']:.3f}s vs "
                 f"{strag['hcmm']['mean_T_wall']:.3f}s)")
    if not thru:
        fail("executor: no throughput rows in the fresh run")
    for r in thru:
        if r.get("n_ok") != r.get("trials"):
            fail(f"executor: {r['n_ok']}/{r['trials']} unpaced trials "
                 f"decoded OK on the {r['backend']} backend")
        rps = r.get("requests_per_sec", 0.0)
        if not (rps > 0.0 and rps == rps and rps != float("inf")):
            fail(f"executor: bogus requests_per_sec={rps!r} on the "
                 f"{r['backend']} backend")


def check_train(fresh: list[dict]) -> None:
    """The train bench's acceptance relations (ISSUE 7), re-checked on the
    fresh quick run — all scale-free, so quick mode only shrinks the step
    count, not the relations:

      * every injection cell carries all three policy arms;
      * coded tokens/sec above uncoded wherever stragglers are injected;
      * coded p99 step time below uncoded at the violent cells
        (slow_factor >= TRAIN_P99_SLOW);
      * the known-rates oracle bounds both arms (tokens/sec from above,
        p99 from below) — it shares the cost model, so a violated bound
        means the adaptive arm or the model itself regressed;
      * every real-jit fidelity row (exact recovery, unrecoverable-mask
        skip, compressed convergence) passed."""
    eps = 1e-9
    cells: dict[tuple, dict] = {}
    fidelity = []
    for r in fresh:
        if r.get("bench") == "train_fidelity":
            fidelity.append(r)
        elif r.get("bench") == "train_coded":
            cells.setdefault((r["onset"], r["slow_factor"]), {})[r["policy"]] = r
    if not cells:
        fail("train: no train_coded rows in the fresh run")
    for key, pols in cells.items():
        if not {"uncoded", "coded", "oracle"} <= set(pols):
            fail(f"train: cell {key} missing a policy arm (have {sorted(pols)})")
            continue
        un, co, orc = pols["uncoded"], pols["coded"], pols["oracle"]
        if orc["tokens_per_sec"] < max(un["tokens_per_sec"],
                                       co["tokens_per_sec"]) - eps:
            fail(f"train: oracle tokens/sec not an upper bound in {key}")
        if orc["p99_step"] > min(un["p99_step"], co["p99_step"]) + eps:
            fail(f"train: oracle p99 not a lower bound in {key}")
        if key[0] > 0 and co["tokens_per_sec"] <= un["tokens_per_sec"]:
            fail(f"train: coded tokens/sec not above uncoded in {key} "
                 f"({co['tokens_per_sec']:.1f} <= {un['tokens_per_sec']:.1f})")
        if key[0] > 0 and key[1] >= TRAIN_P99_SLOW \
                and co["p99_step"] >= un["p99_step"]:
            fail(f"train: coded p99 not below uncoded in {key} "
                 f"({co['p99_step']:.2f} >= {un['p99_step']:.2f})")
    if not fidelity:
        fail("train: no fidelity rows in the fresh run")
    for r in fidelity:
        if not r.get("passed", False):
            fail(f"train: fidelity check failed: {r.get('check')} "
                 f"({r.get('note')})")


def check_kernels(fresh: list[dict]) -> None:
    seen: dict[tuple, set] = {}
    for r in fresh:
        seen.setdefault((r["kernel"],), set()).add(r["mode"])
    for (kernel,), modes in seen.items():
        if not {"interpret", "off"} <= modes:
            fail(f"kernels: {kernel} missing a mode (have {sorted(modes)})")
    if ("gaussian_encode",) not in seen:
        fail("kernels: encode kernel (gaussian_encode) rows missing")


def check_autotune(committed: dict, fresh: dict | None) -> None:
    """Static health of the committed dispatch table, plus (when a fresh
    quick re-measure is available) committed-vs-fresh consistency."""
    entries = committed.get("entries", [])
    if not entries:
        fail("autotune: committed table has no entries")
        return
    for e in entries:
        where = f"{e['op']} {e['shape']} [{e['backend']}]"
        if e.get("mode") == "interpret":
            fail(f"autotune: committed winner is interpret-mode at {where} — "
                 f"the table was built in an interpreter environment")
        err = e.get("model_error")
        if e.get("source") == "measured" and err is not None \
                and err > MODEL_ERROR_BOUND:
            fail(f"autotune: winner at {where} is {err:.2f}x off the cost "
                 f"model (> {MODEL_ERROR_BOUND}x) — roofline constants or "
                 f"the measurement are wrong")
    if fresh is None:
        return
    by_key = {(e["op"], e["backend"], e["shape"]): e for e in entries}
    for fe in fresh.get("entries", []):
        if fe.get("source") != "measured":
            continue
        key = (fe["op"], fe["backend"], fe["shape"])
        ce = by_key.get(key)
        if ce is None:
            fail(f"autotune: committed table has no entry for re-measured "
                 f"cell {key} — regenerate with tools/autotune.py")
            continue
        live = [c for c in fe.get("candidates", []) if not c.get("excluded")]
        if not live:
            continue
        best_us = min(c["us"] for c in live)
        mine = [c for c in live if c["impl"] == ce["impl"]]
        if not mine:
            fail(f"autotune: committed winner {ce['impl']} at {key} was not "
                 f"among the fresh candidates")
            continue
        ratio = mine[0]["us"] / best_us
        if ratio > AUTOTUNE_TOL:
            fail(f"autotune: committed winner {ce['impl']} at {key} is "
                 f"{ratio:.2f}x slower than the fresh best (> {AUTOTUNE_TOL}x"
                 f") — the table is stale for this host")
        else:
            print(f"autotune ok: {key} committed={ce['impl']} "
                  f"fresh-best-ratio={ratio:.2f}x")


def upload_encode_rows(fresh: list[dict]) -> None:
    """Merge the fresh encode-kernel rows into the committed kernels.json —
    keyed by (kernel, mode, shape), so a rerun refreshes ITS OWN shapes in
    place and never replaces rows measured at other (e.g. full-mode)
    shapes — this tool always runs --quick, and overwriting full-mode rows
    would be the exact clobbering incident it exists to prevent."""
    path = os.path.join(BASELINE_DIR, "kernels.json")
    with open(path) as f:
        committed = json.load(f)
    new = [r for r in fresh if r["kernel"] == "gaussian_encode"]
    if not new:
        return
    key = lambda r: (r["kernel"], r["mode"], r["shape"])  # noqa: E731
    new_keys = {key(r) for r in new}
    keep = [r for r in committed if key(r) not in new_keys]
    with open(path, "w") as f:
        json.dump(keep + new, f, indent=1, default=float)
    print(f"uploaded {len(new)} gaussian_encode rows into reports/bench/kernels.json")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scratch", default=os.path.join(REPO, "reports", "bench-ci"),
                    help="scratch dir the quick run writes to (never reports/bench)")
    ap.add_argument("--skip-run", action="store_true",
                    help="diff existing scratch artifacts without rerunning")
    ap.add_argument("--autotune-only", action="store_true",
                    help="re-measure the quick autotune grid into the scratch "
                         "dir and run only the autotune consistency checks "
                         "(the CI autotune job)")
    ap.add_argument("--train-only", action="store_true",
                    help="run only the quick train bench into the scratch dir "
                         "and its check_train gate (the CI coded-training job)")
    ap.add_argument("--serve-only", action="store_true",
                    help="run only the quick serve bench into the scratch dir "
                         "and its check_serve gate — batched/scalar bit "
                         "identity, goodput-vs-occupancy monotonicity, WFQ "
                         "no-starvation (the CI serve-batch job)")
    ap.add_argument("--engine-only", action="store_true",
                    help="run only the quick engine bench into the scratch "
                         "dir and its check_engine gate — fused/scalar bit "
                         "identity, K=64 tokens/sec >= K=1, >= 4x host-sync "
                         "reduction (the CI engine-fused job)")
    ap.add_argument("--executor-only", action="store_true",
                    help="run only the quick executor bench into the scratch "
                         "dir and its check_executor gate — wall-clock/oracle "
                         "payload bit identity, paced-schedule sanity band, "
                         "BPCC<=HCMM ordering (the CI executor-wallclock job)")
    args = ap.parse_args()
    scratch = os.path.abspath(args.scratch)
    if os.path.realpath(scratch) == os.path.realpath(BASELINE_DIR):
        print("refusing to use the committed baseline dir as scratch")
        return 1
    env = dict(os.environ, BENCH_REPORT_DIR=scratch)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(REPO, "src"), env.get("PYTHONPATH")] if p
    )
    if args.autotune_only:
        if not args.skip_run:
            cmd = [sys.executable, "tools/autotune.py", "--quick"]
            print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
            proc = subprocess.run(cmd, cwd=REPO, env=env)
            if proc.returncode != 0:
                fail(f"quick autotune run exited {proc.returncode}")
        committed = load(BASELINE_DIR, "autotune")
        fresh = load(scratch, "autotune")
        if committed is not None:
            check_autotune(committed, fresh)
        if _failures:
            print(f"\n{len(_failures)} autotune check(s) failed")
            return 1
        print("\nautotune consistency checks passed")
        return 0
    if args.train_only:
        if not args.skip_run:
            cmd = [sys.executable, "-m", "benchmarks.run", "--quick",
                   "--only", "train"]
            print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
            proc = subprocess.run(cmd, cwd=REPO, env=env)
            if proc.returncode != 0:
                fail(f"quick train bench exited {proc.returncode}")
        baseline = load(BASELINE_DIR, "BENCH_train")
        fresh = load(scratch, "BENCH_train")
        if baseline is not None and fresh is not None:
            check_schema("BENCH_train", baseline, fresh)
        if fresh is not None:
            check_train(fresh)
        if _failures:
            print(f"\n{len(_failures)} train check(s) failed")
            return 1
        print("\ntrain baseline checks passed")
        return 0
    if args.serve_only:
        if not args.skip_run:
            cmd = [sys.executable, "-m", "benchmarks.run", "--quick",
                   "--only", "serve"]
            print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
            proc = subprocess.run(cmd, cwd=REPO, env=env)
            if proc.returncode != 0:
                fail(f"quick serve bench exited {proc.returncode}")
        baseline = load(BASELINE_DIR, "BENCH_serve")
        fresh = load(scratch, "BENCH_serve")
        if baseline is not None and fresh is not None:
            check_schema("BENCH_serve", baseline, fresh)
        if fresh is not None:
            check_serve(fresh)
        if _failures:
            print(f"\n{len(_failures)} serve check(s) failed")
            return 1
        print("\nserve baseline checks passed")
        return 0
    if args.engine_only:
        if not args.skip_run:
            cmd = [sys.executable, "-m", "benchmarks.run", "--quick",
                   "--only", "engine"]
            print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
            proc = subprocess.run(cmd, cwd=REPO, env=env)
            if proc.returncode != 0:
                fail(f"quick engine bench exited {proc.returncode}")
        baseline = load(BASELINE_DIR, "BENCH_engine")
        fresh = load(scratch, "BENCH_engine")
        if baseline is not None and fresh is not None:
            check_schema("BENCH_engine", baseline, fresh)
        if fresh is not None:
            check_engine(fresh)
        if _failures:
            print(f"\n{len(_failures)} engine check(s) failed")
            return 1
        print("\nengine baseline checks passed")
        return 0
    if args.executor_only:
        if not args.skip_run:
            cmd = [sys.executable, "-m", "benchmarks.run", "--quick",
                   "--only", "executor"]
            print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
            proc = subprocess.run(cmd, cwd=REPO, env=env)
            if proc.returncode != 0:
                fail(f"quick executor bench exited {proc.returncode}")
        baseline = load(BASELINE_DIR, "BENCH_executor")
        fresh = load(scratch, "BENCH_executor")
        if baseline is not None and fresh is not None:
            check_schema("BENCH_executor", baseline, fresh)
        if fresh is not None:
            check_executor(fresh)
        if _failures:
            print(f"\n{len(_failures)} executor check(s) failed")
            return 1
        print("\nexecutor baseline checks passed")
        return 0
    if not args.skip_run:
        cmd = [sys.executable, "-m", "benchmarks.run", "--quick", "--only", BLOCKS]
        print("+", " ".join(cmd), f"(BENCH_REPORT_DIR={scratch})")
        proc = subprocess.run(cmd, cwd=REPO, env=env)
        if proc.returncode != 0:
            fail(f"quick benchmark run exited {proc.returncode}")

    fresh_by_name = {}
    for name in FILES:
        baseline = load(BASELINE_DIR, name)
        fresh = load(scratch, name)
        fresh_by_name[name] = fresh
        if baseline is not None and fresh is not None:
            check_schema(name, baseline, fresh)
    if fresh_by_name.get("BENCH_decode"):
        check_decode(fresh_by_name["BENCH_decode"])
    if fresh_by_name.get("BENCH_streaming"):
        check_streaming(fresh_by_name["BENCH_streaming"])
    if fresh_by_name.get("BENCH_adaptive"):
        check_adaptive(fresh_by_name["BENCH_adaptive"])
    if fresh_by_name.get("BENCH_serve"):
        check_serve(fresh_by_name["BENCH_serve"])
    if fresh_by_name.get("BENCH_engine"):
        check_engine(fresh_by_name["BENCH_engine"])
    if fresh_by_name.get("BENCH_train"):
        check_train(fresh_by_name["BENCH_train"])
    if fresh_by_name.get("BENCH_executor"):
        check_executor(fresh_by_name["BENCH_executor"])
    if fresh_by_name.get("kernels"):
        check_kernels(fresh_by_name["kernels"])
        if not _failures:
            upload_encode_rows(fresh_by_name["kernels"])
    committed_tab = load(BASELINE_DIR, "autotune")
    if committed_tab is not None:
        # fresh re-measure only if one already exists in the scratch dir
        # (the quick bench blocks don't produce one; the autotune CI job
        # and --autotune-only do)
        fresh_tab_path = os.path.join(scratch, "autotune.json")
        fresh_tab = load(scratch, "autotune") \
            if os.path.exists(fresh_tab_path) else None
        check_autotune(committed_tab, fresh_tab)

    if _failures:
        print(f"\n{len(_failures)} baseline check(s) failed")
        return 1
    print("\nall baseline checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
